//! Streaming scene-parsing **service** layer: the Movie S1 video
//! workload routed through the real serving stack end to end.
//!
//! [`super::VideoWorkload::run`] folds every frame through the
//! closed-form [`crate::bayes::exact_fusion`] oracle — it never touches
//! the stochastic netlist path, the coordinator, or the anytime
//! policies. This module is the hardware-path counterpart:
//!
//! ```text
//!  scenario script ─► producer thread (scene gen + detector heads)
//!        │ bounded frame channel (overlaps generation with decisions)
//!        ▼
//!  submitter threads ──► prepared fusion plan (CoordinatorHandle::prepare)
//!        │ PlanHandle::submit_blocking per proposed obstacle,
//!        │ bounded in-flight frame window per submitter
//!        ▼
//!  coordinator (dynamic batcher, batch ≥ 32) ─► word-parallel netlist
//!        │ per-decision deadline + anytime reliable-stop Policy
//!        ▼
//!  frame-ordered fold ─► hardware VideoStats ∥ oracle VideoStats
//! ```
//!
//! One visibility-conditioned [`BayesNet`] detection plan per scenario
//! condition is prepared (and decided) up front — the scenario-level
//! hazard context the network path serves.
//!
//! **Throughput accounting.** [`PipelineReport::hardware_fps`] is the
//! virtual-hardware decision rate (completed decisions over accumulated
//! hardware time at 4 µs/bit): at the paper's 100-bit operating point a
//! full sweep is 0.4 ms/decision = the paper's 2,500 fps, and anytime
//! early exits only push the rate up. [`PipelineReport::wall_fps`] is
//! the software frame rate actually sustained by this process.
//!
//! **Determinism.** With one coordinator worker, one submitter, and no
//! wall-clock deadline ([`PipelineConfig::deterministic`]) the whole
//! threaded pipeline is bit-reproducible: frames arrive in generation
//! order, decisions enter the single worker's bank in submission order,
//! and the anytime reliable stop is data-dependent only. Multiple
//! submitters/workers trade that for throughput (the interleaving at
//! the shared banks varies run to run).

use std::collections::VecDeque;
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use crate::bayes::exact_fusion;
use crate::config::AppConfig;
use crate::coordinator::{
    Coordinator, DecisionParams, MetricsSnapshot, PendingDecision, PlanHandle, PlanSpec, Policy,
};
use crate::network::BayesNet;
use crate::{Error, Result};

use super::detector::fusion_input;
use super::{FrameDetections, ScenarioSpec, VideoStats, VideoWorkload, Visibility};

/// Shared handle the submitter threads pull `(frame index, detections)`
/// work items from.
type FrameFeed = Arc<Mutex<mpsc::Receiver<(usize, FrameDetections)>>>;

/// How a scene-parsing run is served.
#[derive(Debug, Clone)]
pub struct PipelineConfig {
    /// The scenario script to stream.
    pub scenario: ScenarioSpec,
    /// Frames to parse.
    pub frames: usize,
    /// Master seed (scene generator, detector noise, worker banks).
    pub seed: u64,
    /// Stochastic stream length per decision. The paper's operating
    /// point is 100 bits = 0.4 ms/decision = 2,500 fps of virtual
    /// hardware; larger values trade fps for accuracy (Fig. 3d).
    pub bits: usize,
    /// Coordinator worker threads.
    pub workers: usize,
    /// Submitter threads pumping frames into the prepared plan.
    pub submitters: usize,
    /// Frames each submitter keeps in flight before draining the
    /// oldest (the pipelining depth).
    pub inflight_frames: usize,
    /// Dynamic-batcher size (the acceptance runs use ≥ 32).
    pub max_batch: usize,
    /// Per-decision completion deadline, measured from submission.
    pub deadline: Option<Duration>,
    /// Anytime reliable-stop at [`Self::threshold`]: decisions halt as
    /// soon as their confidence interval clears the detection bound.
    pub anytime: bool,
    /// Deadline misses return best-so-far partials instead of errors.
    pub allow_partial: bool,
    /// Detection threshold on posteriors.
    pub threshold: f64,
    /// Pace frame arrivals at this rate (`None` = free-run).
    pub fps_target: Option<f64>,
    /// Record per-stage [`crate::obs::DecisionTrace`]s for the served
    /// decisions (drained into [`PipelineReport::traces`]; the CLI's
    /// `--trace-out` writes them as Chrome `trace_event` JSON).
    pub trace: bool,
    /// Write the Prometheus-style metrics exposition to this file
    /// periodically during the run (and once more at the end) — the
    /// CLI's `--metrics-out`.
    pub metrics_out: Option<std::path::PathBuf>,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        Self {
            scenario: ScenarioSpec::mixed_traffic(),
            frames: 256,
            seed: 42,
            bits: 100,
            workers: 2,
            submitters: 2,
            inflight_frames: 8,
            max_batch: 32,
            deadline: Some(Duration::from_micros(400)),
            anytime: true,
            allow_partial: true,
            threshold: 0.5,
            fps_target: None,
            trace: false,
            metrics_out: None,
        }
    }
}

impl PipelineConfig {
    /// A bit-reproducible configuration: one worker, one submitter, no
    /// wall-clock deadline (anytime early exit stays on — it is
    /// data-dependent, so it cannot break reproducibility).
    pub fn deterministic(scenario: ScenarioSpec, frames: usize, seed: u64, bits: usize) -> Self {
        Self {
            scenario,
            frames,
            seed,
            bits,
            workers: 1,
            submitters: 1,
            deadline: None,
            allow_partial: false,
            fps_target: None,
            ..Self::default()
        }
    }

    /// Does this configuration guarantee bit-identical stats across
    /// runs on the same seed?
    pub fn is_deterministic(&self) -> bool {
        self.workers == 1 && self.submitters == 1 && self.deadline.is_none()
    }

    fn validate(&self) -> Result<()> {
        if self.frames == 0 {
            return Err(Error::Config("pipeline.frames must be > 0".into()));
        }
        if self.workers == 0 || self.submitters == 0 {
            return Err(Error::Config("pipeline workers/submitters must be > 0".into()));
        }
        if !(0.0..=1.0).contains(&self.threshold) {
            return Err(Error::Config(format!(
                "pipeline.threshold must be a probability, got {}",
                self.threshold
            )));
        }
        if self.fps_target.is_some_and(|fps| !fps.is_finite() || fps <= 0.0) {
            return Err(Error::Config(format!(
                "pipeline.fps_target must be > 0, got {:?}",
                self.fps_target
            )));
        }
        Ok(())
    }
}

/// The scenario-level hazard context served through one
/// visibility-conditioned network plan.
#[derive(Debug, Clone)]
pub struct ScenarioContext {
    /// The condition this context was evaluated under.
    pub visibility: Visibility,
    /// Hardware posterior `P(hazard | alert = 1)`.
    pub posterior: f64,
    /// Closed-form reference (enumerated once at prepare time).
    pub exact: f64,
}

/// What a scene-parsing run measured.
#[derive(Debug, Clone)]
pub struct PipelineReport {
    /// Scenario name.
    pub scenario: String,
    /// Frames parsed.
    pub frames: usize,
    /// Detection stats with fused posteriors from the **stochastic
    /// hardware path** (plan-served decisions).
    pub hardware: VideoStats,
    /// The same obstacles folded through the closed-form oracle.
    pub oracle: VideoStats,
    /// Per-visibility breakdown `(condition, hardware, oracle)` for the
    /// conditions that actually occurred.
    pub by_visibility: Vec<(Visibility, VideoStats, VideoStats)>,
    /// Scenario hazard context per visibility (the network-plan path).
    pub context: Vec<ScenarioContext>,
    /// Fusion decisions answered with a deadline miss (only possible
    /// when partial results are disallowed); scored as the
    /// uninformative ½ in [`Self::hardware`].
    pub deadline_missed: u64,
    /// Wall-clock duration of the streaming phase.
    pub wall: Duration,
    /// Software frames per second actually sustained.
    pub wall_fps: f64,
    /// Virtual-hardware decision rate: completed decisions over
    /// accumulated hardware time (4 µs per streamed bit) — the paper's
    /// 2,500 fps metric.
    pub hardware_fps: f64,
    /// Coordinator metrics at the end of the run.
    pub snapshot: MetricsSnapshot,
    /// Per-stage decision traces retained by the recorder ring (empty
    /// unless [`PipelineConfig::trace`] was on). Render with
    /// [`crate::obs::chrome_trace_json`].
    pub traces: Vec<crate::obs::DecisionTrace>,
}

impl PipelineReport {
    /// |hardware fused rate − oracle fused rate| over the whole run
    /// (the bench's per-scenario accuracy gap).
    pub fn fused_rate_gap(&self) -> f64 {
        (self.hardware.rate(self.hardware.fused_detections)
            - self.oracle.rate(self.oracle.fused_detections))
        .abs()
    }

    /// Render a compact text report.
    pub fn to_table(&self) -> String {
        let mut out = String::new();
        let hw = &self.hardware;
        let or = &self.oracle;
        out.push_str(&format!(
            "scenario '{}': {} frames, {} obstacles, {} context conditions\n",
            self.scenario,
            self.frames,
            hw.obstacles,
            self.context.len()
        ));
        out.push_str(&format!(
            "detection rates      rgb {:.3}  thermal {:.3}  fused(hw) {:.3}  fused(oracle) {:.3}\n",
            hw.rate(hw.rgb_detections),
            hw.rate(hw.thermal_detections),
            hw.rate(hw.fused_detections),
            or.rate(or.fused_detections),
        ));
        out.push_str(&format!(
            "fusion gains (hw)    {:+.0} % vs thermal, {:+.0} % vs rgb   (paper: +85 % / +19 %)\n",
            hw.gain_vs_thermal() * 100.0,
            hw.gain_vs_rgb() * 100.0,
        ));
        for (vis, h, o) in &self.by_visibility {
            out.push_str(&format!(
                "  {vis:<10?} {:>4} obstacles: fused hw {:.3} vs oracle {:.3}\n",
                h.obstacles,
                h.rate(h.fused_detections),
                o.rate(o.fused_detections),
            ));
        }
        for c in &self.context {
            out.push_str(&format!(
                "  context {:<10?} P(hazard|alert) = {:.3} (exact {:.3})\n",
                c.visibility, c.posterior, c.exact,
            ));
        }
        out.push_str(&format!(
            "throughput           {:.0} fps software, {:.0} fps virtual hardware \
             (paper: 2,500)\n",
            self.wall_fps, self.hardware_fps,
        ));
        out.push_str(&format!(
            "deadline misses {}  oracle gap {:.4}\n",
            self.deadline_missed,
            self.fused_rate_gap(),
        ));
        out
    }
}

/// The baked `P(hazard)` prior of [`scenario_network`] — the value a
/// [`crate::coordinator::NetworkOverride`] on `("hazard", row 0)`
/// replaces, and the starting belief of the recursive filter
/// ([`super::tracker`]).
pub const HAZARD_BAKED_PRIOR: f64 = 0.35;

/// The visibility-conditioned scenario hazard network: a 5-node DAG
/// whose CPTs are conditioned on the ambient [`Visibility`] (degraded
/// sensing prior from the attenuation, an ambient-light-dependent RGB
/// head, a light-blind thermal head, and an OR-ish alert). Queried as
/// `P(hazard | alert = 1)` by the pipeline's context plans.
pub fn scenario_network(vis: Visibility) -> BayesNet {
    scenario_network_with_prior(vis, HAZARD_BAKED_PRIOR)
}

/// [`scenario_network`] with an explicit hazard prior — the closed-form
/// counterpart of overriding `("hazard", row 0)` on a prepared plan.
/// The tracker's forward-algorithm reference rebuilds the net with its
/// own filtered belief here, so the reference chain never touches the
/// plan layer it is checking.
pub fn scenario_network_with_prior(vis: Visibility, hazard_prior: f64) -> BayesNet {
    let mut net = BayesNet::named(&format!("scene-{vis:?}"));
    // P(hazard): an obstacle on a conflicting path.
    net.add_root("hazard", hazard_prior).expect("fresh net");
    // P(degraded): sensing degradation under this condition.
    let degraded = (0.05 + 0.9 * vis.attenuation()).min(0.95);
    net.add_root("degraded", degraded).expect("fresh net");
    // RGB head: ambient-light-dependent hit rate, halved when degraded.
    // CPT assignment order: first parent (hazard) is the MSB.
    let rgb_hit = 0.12 + 0.78 * vis.ambient_light();
    net.add_node("rgb", &["hazard", "degraded"], &[0.08, 0.05, rgb_hit, rgb_hit * 0.45])
        .expect("valid cpt");
    // Thermal head: light-blind, mildly attenuation-sensitive.
    net.add_node("thermal", &["hazard", "degraded"], &[0.06, 0.05, 0.82, 0.62])
        .expect("valid cpt");
    // Alert: OR-ish over the two heads.
    net.add_node("alert", &["rgb", "thermal"], &[0.02, 0.9, 0.88, 0.98]).expect("valid cpt");
    net
}

/// One obstacle's outcome on both paths.
struct ObstacleOutcome {
    rgb: f64,
    thermal: f64,
    oracle_fused: f64,
    /// `None` = the hardware decision missed its deadline.
    hardware_fused: Option<f64>,
}

/// One frame's resolved outcomes.
struct FrameOutcome {
    idx: usize,
    visibility: Visibility,
    obstacles: Vec<ObstacleOutcome>,
}

/// A submitted frame whose decisions are still in flight.
struct InFlightFrame {
    idx: usize,
    visibility: Visibility,
    raw: Vec<(f64, f64)>,
    oracle: Vec<f64>,
    pending: Vec<Option<PendingDecision>>,
}

/// Stream `config.frames` scenario frames through prepared plans and
/// report hardware-vs-oracle statistics. See the module docs for the
/// thread topology and the determinism contract.
pub fn run(config: &PipelineConfig) -> Result<PipelineReport> {
    config.validate()?;
    let mut app = AppConfig { seed: config.seed, ..AppConfig::default() };
    app.sne.n_bits = config.bits;
    app.coordinator.workers = config.workers;
    app.coordinator.max_batch = config.max_batch.max(1);
    // The batcher must not eat the per-decision deadline waiting for
    // stragglers: flush partial batches well inside the 400 µs budget.
    app.coordinator.max_wait = Duration::from_micros(50);
    app.coordinator.queue_capacity = (config.submitters * config.inflight_frames.max(1) * 16)
        .max(app.coordinator.max_batch)
        .max(256);
    let coord = Coordinator::start(&app)?;
    let handle = coord.handle();
    if config.trace {
        handle.trace_recorder().set_enabled(true);
    }
    // Periodic exposition writer: refresh the metrics file every 250 ms
    // during the stream, plus one final write after the last decision
    // completes (so short runs still land their counters).
    let metrics_writer = config.metrics_out.clone().map(|path| {
        let h = handle.clone();
        let (stop_tx, stop_rx) = mpsc::channel::<()>();
        let jh = std::thread::spawn(move || loop {
            let _ = std::fs::write(&path, h.exposition());
            match stop_rx.recv_timeout(Duration::from_millis(250)) {
                Err(mpsc::RecvTimeoutError::Timeout) => continue,
                // Stop signal, or the run ended and dropped the sender:
                // one final write with the settled counters.
                _ => {
                    let _ = std::fs::write(&path, h.exposition());
                    break;
                }
            }
        });
        (stop_tx, jh)
    });

    let policy = Policy {
        deadline: config.deadline,
        threshold: config.anytime.then_some(config.threshold),
        allow_partial: config.allow_partial,
        ..Policy::default()
    };
    let fusion = handle.prepare(PlanSpec::Fusion { modalities: 2 })?.with_policy(policy);

    // One visibility-conditioned network plan per scenario condition,
    // prepared AND decided before streaming starts: the order of these
    // decisions on the worker banks is fixed, which keeps the
    // single-worker pipeline bit-reproducible.
    let context_policy = Policy {
        threshold: config.anytime.then_some(config.threshold),
        ..Policy::default()
    };
    let mut context = Vec::new();
    for vis in config.scenario.visibilities() {
        let plan = handle
            .prepare(PlanSpec::Network {
                net: Arc::new(scenario_network(vis)),
                query: "hazard".into(),
                evidence: vec![("alert".into(), true)],
            })?
            .with_policy(context_policy);
        let d = plan.decide(DecisionParams::Network { overrides: vec![] })?;
        context.push(ScenarioContext { visibility: vis, posterior: d.posterior, exact: d.exact });
    }

    let workload =
        VideoWorkload::with_generator(config.scenario.generator(config.seed), config.seed);

    let started = Instant::now();
    let outcomes = stream_frames(config, &fusion, workload)?;
    let wall = started.elapsed();

    // Frame-ordered fold: f64 accumulation order is a function of the
    // scenario alone, so deterministic configs produce bit-identical
    // stats.
    let mut hardware = VideoStats::default();
    let mut oracle = VideoStats::default();
    let mut by_vis: [(VideoStats, VideoStats); 5] = Default::default();
    let mut missed = 0u64;
    for frame in &outcomes {
        let vix = Visibility::ALL.iter().position(|&v| v == frame.visibility).unwrap_or(0);
        hardware.frames += 1;
        oracle.frames += 1;
        by_vis[vix].0.frames += 1;
        by_vis[vix].1.frames += 1;
        for o in &frame.obstacles {
            oracle.record(o.rgb, o.thermal, o.oracle_fused, config.threshold);
            by_vis[vix].1.record(o.rgb, o.thermal, o.oracle_fused, config.threshold);
            // A missed deadline claims nothing: score the uninformative
            // prior (= never a detection), exactly like a no-candidate
            // obstacle.
            let hw = match o.hardware_fused {
                Some(p) => p,
                None => {
                    missed += 1;
                    0.5
                }
            };
            hardware.record(o.rgb, o.thermal, hw, config.threshold);
            by_vis[vix].0.record(o.rgb, o.thermal, hw, config.threshold);
        }
    }
    let by_visibility: Vec<(Visibility, VideoStats, VideoStats)> = Visibility::ALL
        .iter()
        .zip(by_vis)
        .filter(|(_, (h, _))| h.frames > 0)
        .map(|(&v, (h, o))| (v, h, o))
        .collect();

    let snapshot = handle.metrics().snapshot();
    let traces = handle.trace_recorder().drain();
    if let Some((stop, jh)) = metrics_writer {
        let _ = stop.send(());
        let _ = jh.join();
    }
    coord.shutdown();
    let wall_secs = wall.as_secs_f64().max(1e-9);
    Ok(PipelineReport {
        scenario: config.scenario.name.to_string(),
        frames: config.frames,
        hardware,
        oracle,
        by_visibility,
        context,
        deadline_missed: missed,
        wall,
        wall_fps: config.frames as f64 / wall_secs,
        hardware_fps: snapshot.virtual_fps(),
        snapshot,
        traces,
    })
}

/// Producer + submitter topology around the prepared fusion plan.
fn stream_frames(
    config: &PipelineConfig,
    plan: &PlanHandle,
    mut workload: VideoWorkload,
) -> Result<Vec<FrameOutcome>> {
    let frames = config.frames;
    let inflight = config.inflight_frames.max(1);
    let channel_cap = (config.submitters * inflight).max(1);
    let (frame_tx, frame_rx) = mpsc::sync_channel::<(usize, FrameDetections)>(channel_cap);
    let feed: FrameFeed = Arc::new(Mutex::new(frame_rx));
    let (out_tx, out_rx) = mpsc::channel::<FrameOutcome>();
    let fps_target = config.fps_target;
    let mut results: Vec<Option<FrameOutcome>> = Vec::new();
    results.resize_with(frames, || None);

    std::thread::scope(|s| -> Result<()> {
        // Producer: scene generation + detector heads overlap the
        // in-flight decisions downstream.
        s.spawn(move || {
            let start = Instant::now();
            for idx in 0..frames {
                if let Some(fps) = fps_target {
                    // Sleep most of the interval, spin only the tail —
                    // a pure yield loop would burn a core for the whole
                    // run and depress the very fps it is pacing.
                    let due = start + Duration::from_secs_f64(idx as f64 / fps);
                    loop {
                        let now = Instant::now();
                        if now >= due {
                            break;
                        }
                        let left = due - now;
                        if left > Duration::from_micros(200) {
                            std::thread::sleep(left - Duration::from_micros(100));
                        } else {
                            std::thread::yield_now();
                        }
                    }
                }
                let det = workload.next_detections();
                if frame_tx.send((idx, det)).is_err() {
                    return; // submitters bailed; stop producing
                }
            }
        });
        let mut submitters = Vec::new();
        for _ in 0..config.submitters {
            let feed = Arc::clone(&feed);
            let tx = out_tx.clone();
            let plan = plan.clone();
            submitters.push(s.spawn(move || submit_loop(&plan, &feed, &tx, inflight)));
        }
        // Only the submitters hold the feed/out senders now, so both
        // channels disconnect (and the producer unblocks) when they
        // finish — on success *or* error.
        drop(feed);
        drop(out_tx);
        for outcome in out_rx {
            let idx = outcome.idx;
            results[idx] = Some(outcome);
        }
        for sub in submitters {
            sub.join()
                .map_err(|_| Error::Coordinator("scene pipeline submitter panicked".into()))??;
        }
        Ok(())
    })?;

    let mut out = Vec::with_capacity(frames);
    for (idx, slot) in results.into_iter().enumerate() {
        out.push(slot.ok_or_else(|| {
            Error::Coordinator(format!("scene pipeline dropped frame {idx}"))
        })?);
    }
    Ok(out)
}

/// One submitter: pull frames, submit the proposed obstacles against
/// the prepared plan, keep `inflight` frames pipelined, resolve in
/// frame order.
fn submit_loop(
    plan: &PlanHandle,
    feed: &FrameFeed,
    tx: &mpsc::Sender<FrameOutcome>,
    inflight: usize,
) -> Result<()> {
    let mut window: VecDeque<InFlightFrame> = VecDeque::with_capacity(inflight + 1);
    loop {
        let msg = feed.lock().expect("scene pipeline feed poisoned").recv();
        let Ok((idx, det)) = msg else { break };
        let mut frame = InFlightFrame {
            idx,
            visibility: det.frame.visibility,
            raw: det.confidences.clone(),
            oracle: Vec::with_capacity(det.confidences.len()),
            pending: Vec::with_capacity(det.confidences.len()),
        };
        for &(p_rgb, p_th) in &det.confidences {
            let (fr, ft) = (fusion_input(p_rgb), fusion_input(p_th));
            frame.oracle.push(exact_fusion(fr, ft));
            // Ref-31 semantics: a fusion decision exists only when at
            // least one modality proposed a box. With neither firing
            // there is nothing to fuse — both paths score the obstacle
            // at the uninformative ½ (never a detection).
            frame.pending.push(if fr > 0.5 || ft > 0.5 {
                Some(plan.submit_blocking(DecisionParams::Fusion { posteriors: vec![fr, ft] })?)
            } else {
                None
            });
        }
        window.push_back(frame);
        while window.len() > inflight {
            resolve_front(&mut window, tx)?;
        }
    }
    while !window.is_empty() {
        resolve_front(&mut window, tx)?;
    }
    Ok(())
}

/// Wait out the oldest in-flight frame and emit its outcomes.
fn resolve_front(
    window: &mut VecDeque<InFlightFrame>,
    tx: &mpsc::Sender<FrameOutcome>,
) -> Result<()> {
    let Some(frame) = window.pop_front() else { return Ok(()) };
    let InFlightFrame { idx, visibility, raw, oracle, pending } = frame;
    let mut obstacles = Vec::with_capacity(raw.len());
    for ((&(rgb, thermal), &oracle_fused), pending) in
        raw.iter().zip(oracle.iter()).zip(pending)
    {
        let hardware_fused = match pending {
            None => Some(0.5), // no candidate box on either modality
            Some(p) => match p.wait() {
                Ok(d) => Some(d.posterior),
                Err(Error::Deadline(_)) => None,
                Err(e) => return Err(e),
            },
        };
        obstacles.push(ObstacleOutcome { rgb, thermal, oracle_fused, hardware_fused });
    }
    let _ = tx.send(FrameOutcome { idx, visibility, obstacles });
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::network::exact_posterior_by_name;

    #[test]
    fn scenario_networks_are_valid_and_visibility_conditioned() {
        let mut posteriors = Vec::new();
        for vis in Visibility::ALL {
            let net = scenario_network(vis);
            net.validate().unwrap();
            let (p, p_ev) =
                exact_posterior_by_name(&net, "hazard", &[("alert", true)]).unwrap();
            assert!((0.0..=1.0).contains(&p), "{vis:?}: posterior {p}");
            assert!(p_ev > 0.05, "{vis:?}: evidence mass {p_ev}");
            posteriors.push(p);
        }
        // Conditioning is real: the hazard posterior differs across
        // visibility conditions (fog's attenuation vs clear day).
        let day = posteriors[0];
        let fog = posteriors[2];
        assert!((day - fog).abs() > 0.005, "day {day} vs fog {fog} indistinguishable");
    }

    #[test]
    fn default_config_is_throughput_shaped_and_deterministic_preset_is_not() {
        let d = PipelineConfig::default();
        assert!(d.max_batch >= 32);
        assert_eq!(d.bits, 100, "the paper's 0.4 ms operating point");
        assert!(d.anytime && d.allow_partial);
        assert!(!d.is_deterministic(), "default overlaps submitters/workers");
        let det =
            PipelineConfig::deterministic(ScenarioSpec::mixed_traffic(), 16, 1, 1024);
        assert!(det.is_deterministic());
        assert!(det.deadline.is_none());
    }

    #[test]
    fn config_validation_rejects_degenerate_runs() {
        let zero = PipelineConfig { frames: 0, ..PipelineConfig::default() };
        assert!(run(&zero).is_err());
        let bad_threshold = PipelineConfig { threshold: 1.5, ..PipelineConfig::default() };
        assert!(bad_threshold.validate().is_err());
        let no_workers = PipelineConfig { workers: 0, ..PipelineConfig::default() };
        assert!(no_workers.validate().is_err());
    }

    #[test]
    fn traced_run_collects_decomposing_traces_and_writes_metrics() {
        let metrics_path = std::env::temp_dir()
            .join(format!("bayes-mem-pipeline-metrics-{}.prom", std::process::id()));
        let cfg = PipelineConfig {
            frames: 8,
            submitters: 1,
            workers: 1,
            bits: 256,
            trace: true,
            metrics_out: Some(metrics_path.clone()),
            ..PipelineConfig::default()
        };
        let report = run(&cfg).unwrap();
        assert!(!report.traces.is_empty(), "tracing was on but no traces retained");
        for t in &report.traces {
            let sum: u64 =
                crate::obs::Stage::ALL.iter().map(|&s| t.stage_ns(s)).sum();
            assert_eq!(sum, t.end_to_end_ns(), "stage spans must decompose end-to-end");
        }
        let json = crate::obs::chrome_trace_json(&report.traces);
        assert_eq!(json.matches('{').count(), json.matches('}').count());
        // Per-stage quantiles made it into the snapshot via the traces.
        assert!(report.snapshot.stage_hist(crate::obs::Stage::Sweep).count() > 0);
        let text = std::fs::read_to_string(&metrics_path).unwrap();
        let _ = std::fs::remove_file(&metrics_path);
        assert!(text.contains("decision_latency_ns{quantile="), "{text}");
        assert!(text.contains("decision_stage_ns{stage=\"sweep\""), "{text}");
    }

    #[test]
    fn small_run_reports_hardware_and_oracle_stats() {
        let cfg = PipelineConfig {
            frames: 12,
            submitters: 2,
            workers: 2,
            bits: 256,
            fps_target: None,
            ..PipelineConfig::default()
        };
        let report = run(&cfg).unwrap();
        assert_eq!(report.frames, 12);
        assert_eq!(report.hardware.frames, 12);
        assert_eq!(report.hardware.obstacles, report.oracle.obstacles);
        assert!(report.hardware.obstacles >= 12);
        assert_eq!(report.context.len(), 5, "default mix spans every visibility");
        assert!(report.hardware_fps > 0.0);
        assert!(report.wall_fps > 0.0);
        let table = report.to_table();
        assert!(table.contains("scenario 'mixed'"), "{table}");
        assert!(table.contains("fps virtual hardware"), "{table}");
        // The per-visibility split conserves obstacles.
        let split: usize =
            report.by_visibility.iter().map(|(_, h, _)| h.obstacles).sum();
        assert_eq!(split, report.hardware.obstacles);
    }
}
