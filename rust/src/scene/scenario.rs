//! Scene and scenario generation.

use crate::util::Rng;

/// Ambient visibility condition of a frame (Fig. 4b's day/night columns
/// plus the fog/rain cases the paper's discussion motivates).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Visibility {
    /// Clear daylight: RGB strong, thermal indifferent.
    Day,
    /// Low-light night: RGB weak, thermal unaffected.
    Night,
    /// Fog: both degraded, thermal less so.
    Fog,
    /// Rain: RGB mildly degraded, thermal mildly degraded.
    Rain,
    /// Harsh glare (the Movie S1 running-child case): RGB strongly
    /// degraded, thermal unaffected.
    HarshLight,
}

impl Visibility {
    /// All conditions, for sweeps.
    pub const ALL: [Visibility; 5] =
        [Visibility::Day, Visibility::Night, Visibility::Fog, Visibility::Rain, Visibility::HarshLight];

    /// Ambient light level seen by the RGB camera, `[0, 1]`.
    pub fn ambient_light(self) -> f64 {
        match self {
            Visibility::Day => 1.0,
            Visibility::Night => 0.15,
            Visibility::Fog => 0.55,
            Visibility::Rain => 0.65,
            Visibility::HarshLight => 0.25, // blown-out sensor ≈ low SNR
        }
    }

    /// Atmospheric attenuation affecting both sensors, `[0, 1]`.
    pub fn attenuation(self) -> f64 {
        match self {
            Visibility::Day => 0.0,
            Visibility::Night => 0.05,
            Visibility::Fog => 0.45,
            Visibility::Rain => 0.25,
            Visibility::HarshLight => 0.05,
        }
    }
}

/// Obstacle category with its typical thermal signature.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ObstacleClass {
    /// Pedestrians: strong heat emitters, medium visual contrast.
    Pedestrian,
    /// Cyclists: strong heat, higher contrast.
    Cyclist,
    /// Running vehicles: warm (engine), high contrast.
    Vehicle,
    /// Parked/cold vehicles: weak heat — the thermal-miss case.
    ParkedVehicle,
    /// Debris/static objects: cold, low contrast — hard for both.
    Debris,
}

impl ObstacleClass {
    /// All classes, for sweeps.
    pub const ALL: [ObstacleClass; 5] = [
        ObstacleClass::Pedestrian,
        ObstacleClass::Cyclist,
        ObstacleClass::Vehicle,
        ObstacleClass::ParkedVehicle,
        ObstacleClass::Debris,
    ];

    /// Nominal heat emission, `[0, 1]`.
    pub fn heat(self) -> f64 {
        match self {
            ObstacleClass::Pedestrian => 0.9,
            ObstacleClass::Cyclist => 0.85,
            ObstacleClass::Vehicle => 0.7,
            ObstacleClass::ParkedVehicle => 0.15,
            ObstacleClass::Debris => 0.08,
        }
    }

    /// Nominal visual contrast, `[0, 1]`.
    pub fn contrast(self) -> f64 {
        match self {
            ObstacleClass::Pedestrian => 0.55,
            ObstacleClass::Cyclist => 0.65,
            ObstacleClass::Vehicle => 0.85,
            ObstacleClass::ParkedVehicle => 0.8,
            ObstacleClass::Debris => 0.35,
        }
    }

    /// Nominal angular size, `[0, 1]`.
    pub fn size(self) -> f64 {
        match self {
            ObstacleClass::Pedestrian => 0.35,
            ObstacleClass::Cyclist => 0.45,
            ObstacleClass::Vehicle => 0.9,
            ObstacleClass::ParkedVehicle => 0.9,
            ObstacleClass::Debris => 0.25,
        }
    }
}

/// One ground-truth obstacle in a frame.
#[derive(Debug, Clone)]
pub struct Obstacle {
    /// Category.
    pub class: ObstacleClass,
    /// Heat emission after per-instance jitter, `[0, 1]`.
    pub heat: f64,
    /// Visual contrast after jitter, `[0, 1]`.
    pub contrast: f64,
    /// Normalised distance, `[0, 1]` (1 = sensing-range limit).
    pub distance: f64,
    /// Angular size, `[0, 1]`.
    pub size: f64,
}

impl Obstacle {
    /// Sample an instance of `class` with per-instance jitter.
    pub fn sample(class: ObstacleClass, rng: &mut Rng) -> Self {
        let jit = |x: f64, rng: &mut Rng| (x + rng.normal_with(0.0, 0.08)).clamp(0.02, 1.0);
        Self {
            class,
            heat: jit(class.heat(), rng),
            contrast: jit(class.contrast(), rng),
            distance: rng.range_f64(0.1, 1.0),
            size: jit(class.size(), rng),
        }
    }

    /// The 6-feature descriptor consumed by the detector heads (and the
    /// L2 JAX model): `[heat, contrast, ambient, attenuation, distance,
    /// size]`.
    pub fn features(&self, vis: Visibility) -> [f64; 6] {
        [
            self.heat,
            self.contrast,
            vis.ambient_light(),
            vis.attenuation(),
            self.distance,
            self.size,
        ]
    }
}

/// One frame: a visibility condition plus ground-truth obstacles.
#[derive(Debug, Clone)]
pub struct SceneFrame {
    /// Monotone frame id.
    pub id: u64,
    /// Ambient condition.
    pub visibility: Visibility,
    /// Ground-truth obstacles.
    pub obstacles: Vec<Obstacle>,
}

/// Streaming generator of scene frames.
#[derive(Debug, Clone)]
pub struct SceneGenerator {
    rng: Rng,
    next_id: u64,
    /// Mean obstacles per frame.
    pub mean_obstacles: f64,
    /// Condition mix: `(visibility, weight)`.
    pub condition_mix: Vec<(Visibility, f64)>,
}

impl SceneGenerator {
    /// Generator with the default day/night-heavy mix.
    pub fn new(seed: u64) -> Self {
        Self {
            rng: Rng::seeded(seed),
            next_id: 0,
            mean_obstacles: 3.0,
            condition_mix: vec![
                (Visibility::Day, 0.4),
                (Visibility::Night, 0.3),
                (Visibility::Fog, 0.1),
                (Visibility::Rain, 0.1),
                (Visibility::HarshLight, 0.1),
            ],
        }
    }

    /// Fix the generator to one condition (Fig. 4b per-column runs).
    pub fn with_condition(seed: u64, vis: Visibility) -> Self {
        let mut g = Self::new(seed);
        g.condition_mix = vec![(vis, 1.0)];
        g
    }

    fn sample_condition(&mut self) -> Visibility {
        let total: f64 = self.condition_mix.iter().map(|(_, w)| w).sum();
        let mut u = self.rng.f64() * total;
        for &(v, w) in &self.condition_mix {
            if u < w {
                return v;
            }
            u -= w;
        }
        self.condition_mix.last().map(|&(v, _)| v).unwrap_or(Visibility::Day)
    }

    /// Generate the next frame.
    pub fn next_frame(&mut self) -> SceneFrame {
        let visibility = self.sample_condition();
        // Poisson-ish obstacle count via thinning (knuth for small mean).
        let mut n = 0usize;
        let l = (-self.mean_obstacles).exp();
        let mut p = 1.0;
        loop {
            p *= self.rng.f64();
            if p <= l {
                break;
            }
            n += 1;
        }
        let n = n.clamp(1, 8);
        let obstacles = (0..n)
            .map(|_| {
                let class = ObstacleClass::ALL[self.rng.below(ObstacleClass::ALL.len())];
                Obstacle::sample(class, &mut self.rng)
            })
            .collect();
        let id = self.next_id;
        self.next_id += 1;
        SceneFrame { id, visibility, obstacles }
    }

    /// Generate `n` frames.
    pub fn frames(&mut self, n: usize) -> Vec<SceneFrame> {
        (0..n).map(|_| self.next_frame()).collect()
    }
}

/// The Fig. 3 route-planning scenario: a vehicle weighing a lane change.
///
/// Maps traffic context onto the inference operator's three inputs.
#[derive(Debug, Clone)]
pub struct LaneChangeScenario {
    /// Prior belief the cut-in is viable, from traffic context `P(A)`.
    pub prior_cut_in: f64,
    /// Probability of observing the target-lane evidence given the cut-in
    /// is viable, `P(B|A)`.
    pub evidence_given_viable: f64,
    /// Same evidence probability when the cut-in is not viable, `P(B|¬A)`.
    pub evidence_given_blocked: f64,
}

impl LaneChangeScenario {
    /// The paper's Fig. 3b instance (P(A)=57 %, P(B)≈72 %, posterior ≈61 %).
    pub fn fig3b() -> Self {
        Self {
            prior_cut_in: 0.57,
            evidence_given_viable: 0.77,
            evidence_given_blocked: 0.655,
        }
    }

    /// Randomised scenario for workload generation: prior from traffic
    /// density, likelihoods from sensor quality.
    pub fn sample(rng: &mut Rng) -> Self {
        let prior = rng.range_f64(0.2, 0.85);
        let quality = rng.range_f64(0.6, 0.95);
        Self {
            prior_cut_in: prior,
            evidence_given_viable: quality,
            evidence_given_blocked: (1.0 - quality) + rng.range_f64(0.0, 0.3),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generator_is_deterministic_per_seed() {
        let mut a = SceneGenerator::new(7);
        let mut b = SceneGenerator::new(7);
        let fa = a.next_frame();
        let fb = b.next_frame();
        assert_eq!(fa.obstacles.len(), fb.obstacles.len());
        assert_eq!(fa.visibility, fb.visibility);
        assert_eq!(fa.id, 0);
        assert_eq!(a.next_frame().id, 1);
    }

    #[test]
    fn frames_have_bounded_attributes() {
        let mut g = SceneGenerator::new(8);
        for f in g.frames(200) {
            assert!(!f.obstacles.is_empty() && f.obstacles.len() <= 8);
            for o in &f.obstacles {
                for v in [o.heat, o.contrast, o.distance, o.size] {
                    assert!((0.0..=1.0).contains(&v), "{o:?}");
                }
                let feats = o.features(f.visibility);
                assert!(feats.iter().all(|x| (0.0..=1.0).contains(x)));
            }
        }
    }

    #[test]
    fn condition_mix_respected() {
        let mut g = SceneGenerator::with_condition(9, Visibility::Night);
        assert!(g.frames(50).iter().all(|f| f.visibility == Visibility::Night));
    }

    #[test]
    fn class_signatures_separate_modal_failure_modes() {
        // Parked vehicles are cold but visible; pedestrians warm but lower
        // contrast — the complementarity fusion exploits.
        assert!(ObstacleClass::ParkedVehicle.heat() < 0.3);
        assert!(ObstacleClass::ParkedVehicle.contrast() > 0.6);
        assert!(ObstacleClass::Pedestrian.heat() > 0.8);
    }

    #[test]
    fn fig3b_scenario_matches_paper_constants() {
        let s = LaneChangeScenario::fig3b();
        let pb = s.prior_cut_in * s.evidence_given_viable
            + (1.0 - s.prior_cut_in) * s.evidence_given_blocked;
        assert!((pb - 0.72).abs() < 0.005);
        let mut rng = Rng::seeded(1);
        for _ in 0..100 {
            let r = LaneChangeScenario::sample(&mut rng);
            assert!((0.0..=1.0).contains(&r.prior_cut_in));
            assert!((0.0..=1.0).contains(&r.evidence_given_viable));
            assert!((0.0..=1.0).contains(&r.evidence_given_blocked));
        }
    }
}
