//! Scene and scenario generation.

use crate::util::Rng;

/// Ambient visibility condition of a frame (Fig. 4b's day/night columns
/// plus the fog/rain cases the paper's discussion motivates).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Visibility {
    /// Clear daylight: RGB strong, thermal indifferent.
    Day,
    /// Low-light night: RGB weak, thermal unaffected.
    Night,
    /// Fog: both degraded, thermal less so.
    Fog,
    /// Rain: RGB mildly degraded, thermal mildly degraded.
    Rain,
    /// Harsh glare (the Movie S1 running-child case): RGB strongly
    /// degraded, thermal unaffected.
    HarshLight,
}

impl Visibility {
    /// All conditions, for sweeps.
    pub const ALL: [Visibility; 5] =
        [Visibility::Day, Visibility::Night, Visibility::Fog, Visibility::Rain, Visibility::HarshLight];

    /// Ambient light level seen by the RGB camera, `[0, 1]`.
    pub fn ambient_light(self) -> f64 {
        match self {
            Visibility::Day => 1.0,
            Visibility::Night => 0.15,
            Visibility::Fog => 0.55,
            Visibility::Rain => 0.65,
            Visibility::HarshLight => 0.25, // blown-out sensor ≈ low SNR
        }
    }

    /// Atmospheric attenuation affecting both sensors, `[0, 1]`.
    pub fn attenuation(self) -> f64 {
        match self {
            Visibility::Day => 0.0,
            Visibility::Night => 0.05,
            Visibility::Fog => 0.45,
            Visibility::Rain => 0.25,
            Visibility::HarshLight => 0.05,
        }
    }
}

/// Obstacle category with its typical thermal signature.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ObstacleClass {
    /// Pedestrians: strong heat emitters, medium visual contrast.
    Pedestrian,
    /// Cyclists: strong heat, higher contrast.
    Cyclist,
    /// Running vehicles: warm (engine), high contrast.
    Vehicle,
    /// Parked/cold vehicles: weak heat — the thermal-miss case.
    ParkedVehicle,
    /// Debris/static objects: cold, low contrast — hard for both.
    Debris,
}

impl ObstacleClass {
    /// All classes, for sweeps.
    pub const ALL: [ObstacleClass; 5] = [
        ObstacleClass::Pedestrian,
        ObstacleClass::Cyclist,
        ObstacleClass::Vehicle,
        ObstacleClass::ParkedVehicle,
        ObstacleClass::Debris,
    ];

    /// Nominal heat emission, `[0, 1]`.
    pub fn heat(self) -> f64 {
        match self {
            ObstacleClass::Pedestrian => 0.9,
            ObstacleClass::Cyclist => 0.85,
            ObstacleClass::Vehicle => 0.7,
            ObstacleClass::ParkedVehicle => 0.15,
            ObstacleClass::Debris => 0.08,
        }
    }

    /// Nominal visual contrast, `[0, 1]`.
    pub fn contrast(self) -> f64 {
        match self {
            ObstacleClass::Pedestrian => 0.55,
            ObstacleClass::Cyclist => 0.65,
            ObstacleClass::Vehicle => 0.85,
            ObstacleClass::ParkedVehicle => 0.8,
            ObstacleClass::Debris => 0.35,
        }
    }

    /// Nominal angular size, `[0, 1]`.
    pub fn size(self) -> f64 {
        match self {
            ObstacleClass::Pedestrian => 0.35,
            ObstacleClass::Cyclist => 0.45,
            ObstacleClass::Vehicle => 0.9,
            ObstacleClass::ParkedVehicle => 0.9,
            ObstacleClass::Debris => 0.25,
        }
    }
}

/// One ground-truth obstacle in a frame.
#[derive(Debug, Clone)]
pub struct Obstacle {
    /// Category.
    pub class: ObstacleClass,
    /// Heat emission after per-instance jitter, `[0, 1]`.
    pub heat: f64,
    /// Visual contrast after jitter, `[0, 1]`.
    pub contrast: f64,
    /// Normalised distance, `[0, 1]` (1 = sensing-range limit).
    pub distance: f64,
    /// Angular size, `[0, 1]`.
    pub size: f64,
}

impl Obstacle {
    /// Sample an instance of `class` with per-instance jitter.
    pub fn sample(class: ObstacleClass, rng: &mut Rng) -> Self {
        let jit = |x: f64, rng: &mut Rng| (x + rng.normal_with(0.0, 0.08)).clamp(0.02, 1.0);
        Self {
            class,
            heat: jit(class.heat(), rng),
            contrast: jit(class.contrast(), rng),
            distance: rng.range_f64(0.1, 1.0),
            size: jit(class.size(), rng),
        }
    }

    /// The 6-feature descriptor consumed by the detector heads (and the
    /// L2 JAX model): `[heat, contrast, ambient, attenuation, distance,
    /// size]`.
    pub fn features(&self, vis: Visibility) -> [f64; 6] {
        [
            self.heat,
            self.contrast,
            vis.ambient_light(),
            vis.attenuation(),
            self.distance,
            self.size,
        ]
    }
}

/// One frame: a visibility condition plus ground-truth obstacles.
#[derive(Debug, Clone)]
pub struct SceneFrame {
    /// Monotone frame id.
    pub id: u64,
    /// Ambient condition.
    pub visibility: Visibility,
    /// Ground-truth obstacles.
    pub obstacles: Vec<Obstacle>,
}

/// One phase of a scenario script: how long it lasts and what the world
/// looks like while it does. Phases are cycled by [`SceneGenerator`]
/// (see [`SceneGenerator::scripted`]).
#[derive(Debug, Clone)]
pub struct ScenarioPhase {
    /// Frames this phase lasts before the script advances (min 1).
    pub frames: usize,
    /// Visibility mix while the phase is active.
    pub condition_mix: Vec<(Visibility, f64)>,
    /// Obstacle-class mix while the phase is active.
    pub class_mix: Vec<(ObstacleClass, f64)>,
}

/// A named scenario script: the Movie S1 cases (pedestrian-heavy night,
/// foggy highway, glare burst, …) as reusable generator programs. Feed
/// one to [`SceneGenerator::scripted`] via [`Self::generator`], or to
/// the streaming service layer via
/// [`crate::scene::pipeline::PipelineConfig`].
#[derive(Debug, Clone)]
pub struct ScenarioSpec {
    /// Registry name (`bayes-mem parse-video --scenario <name>`).
    pub name: &'static str,
    /// One-line description for `--list-scenarios`.
    pub description: &'static str,
    /// Mean obstacles per frame.
    pub mean_obstacles: f64,
    /// The phases, cycled in order for as long as frames are drawn.
    pub phases: Vec<ScenarioPhase>,
}

/// Uniform weights over every obstacle class (the legacy draw).
fn uniform_classes() -> Vec<(ObstacleClass, f64)> {
    ObstacleClass::ALL.iter().map(|&c| (c, 1.0)).collect()
}

impl ScenarioSpec {
    /// The default Movie S1 mix: day/night-heavy conditions, uniform
    /// obstacle classes — identical in distribution to
    /// [`SceneGenerator::new`].
    pub fn mixed_traffic() -> Self {
        Self {
            name: "mixed",
            description: "default day/night-heavy mix, uniform obstacle classes",
            mean_obstacles: 3.0,
            phases: vec![ScenarioPhase {
                frames: 1,
                condition_mix: vec![
                    (Visibility::Day, 0.4),
                    (Visibility::Night, 0.3),
                    (Visibility::Fog, 0.1),
                    (Visibility::Rain, 0.1),
                    (Visibility::HarshLight, 0.1),
                ],
                class_mix: uniform_classes(),
            }],
        }
    }

    /// Pedestrian-heavy night traffic: the regime where RGB is blind and
    /// thermal carries the fusion (the paper's biggest gain case).
    pub fn night_pedestrians() -> Self {
        Self {
            name: "night-pedestrians",
            description: "dense pedestrians/cyclists at night (RGB-blind regime)",
            mean_obstacles: 3.5,
            phases: vec![ScenarioPhase {
                frames: 1,
                condition_mix: vec![(Visibility::Night, 1.0)],
                class_mix: vec![
                    (ObstacleClass::Pedestrian, 0.55),
                    (ObstacleClass::Cyclist, 0.2),
                    (ObstacleClass::Vehicle, 0.1),
                    (ObstacleClass::ParkedVehicle, 0.1),
                    (ObstacleClass::Debris, 0.05),
                ],
            }],
        }
    }

    /// Foggy highway: attenuated sensing, cold vehicles and debris —
    /// the thermal-miss regime.
    pub fn foggy_highway() -> Self {
        Self {
            name: "foggy-highway",
            description: "fog/rain highway with cold vehicles and debris (thermal-miss regime)",
            mean_obstacles: 2.5,
            phases: vec![ScenarioPhase {
                frames: 1,
                condition_mix: vec![(Visibility::Fog, 0.8), (Visibility::Rain, 0.2)],
                class_mix: vec![
                    (ObstacleClass::Vehicle, 0.45),
                    (ObstacleClass::ParkedVehicle, 0.25),
                    (ObstacleClass::Debris, 0.2),
                    (ObstacleClass::Cyclist, 0.05),
                    (ObstacleClass::Pedestrian, 0.05),
                ],
            }],
        }
    }

    /// Glare burst: clear daylight punctuated by harsh-light bursts with
    /// vulnerable road users (the Movie S1 running-child case).
    pub fn glare_burst() -> Self {
        Self {
            name: "glare-burst",
            description: "daylight with periodic glare bursts over pedestrians (Movie S1 case)",
            mean_obstacles: 3.0,
            phases: vec![
                ScenarioPhase {
                    frames: 16,
                    condition_mix: vec![(Visibility::Day, 1.0)],
                    class_mix: uniform_classes(),
                },
                ScenarioPhase {
                    frames: 8,
                    condition_mix: vec![(Visibility::HarshLight, 1.0)],
                    class_mix: vec![
                        (ObstacleClass::Pedestrian, 0.5),
                        (ObstacleClass::Cyclist, 0.25),
                        (ObstacleClass::Vehicle, 0.15),
                        (ObstacleClass::ParkedVehicle, 0.05),
                        (ObstacleClass::Debris, 0.05),
                    ],
                },
            ],
        }
    }

    /// Sweep all five [`Visibility`] conditions in fixed-length phases
    /// (the Fig. 4b columns as one continuous drive).
    pub fn visibility_sweep() -> Self {
        Self {
            name: "visibility-sweep",
            description: "cycles every visibility condition in 12-frame phases",
            mean_obstacles: 3.0,
            phases: Visibility::ALL
                .iter()
                .map(|&vis| ScenarioPhase {
                    frames: 12,
                    condition_mix: vec![(vis, 1.0)],
                    class_mix: uniform_classes(),
                })
                .collect(),
        }
    }

    /// Tracked foggy highway: the [`Self::foggy_highway`] world consumed
    /// by the recursive filtering loop ([`crate::scene::tracker`]) —
    /// each frame's served posterior becomes the next frame's prior
    /// binding on one prepared plan.
    pub fn tracked_foggy_highway() -> Self {
        Self {
            name: "tracked-foggy-highway",
            description: "foggy highway under recursive per-frame belief tracking",
            ..Self::foggy_highway()
        }
    }

    /// Tracked night pedestrians: [`Self::night_pedestrians`] under the
    /// recursive filtering loop.
    pub fn tracked_night_pedestrians() -> Self {
        Self {
            name: "tracked-night-pedestrians",
            description: "night pedestrians under recursive per-frame belief tracking",
            ..Self::night_pedestrians()
        }
    }

    /// Tracked glare burst: [`Self::glare_burst`] under the recursive
    /// filtering loop (belief carried through the harsh-light bursts).
    pub fn tracked_glare_burst() -> Self {
        Self {
            name: "tracked-glare-burst",
            description: "glare bursts under recursive per-frame belief tracking",
            ..Self::glare_burst()
        }
    }

    /// `true` for the `tracked-*` family: scenarios whose frames are
    /// folded through the recursive Bayesian filter instead of decided
    /// independently.
    pub fn is_tracked(&self) -> bool {
        self.name.starts_with("tracked-")
    }

    /// Every registered scenario.
    pub fn all() -> Vec<ScenarioSpec> {
        vec![
            Self::mixed_traffic(),
            Self::night_pedestrians(),
            Self::foggy_highway(),
            Self::glare_burst(),
            Self::visibility_sweep(),
            Self::tracked_foggy_highway(),
            Self::tracked_night_pedestrians(),
            Self::tracked_glare_burst(),
        ]
    }

    /// Look a scenario up by its registry name.
    pub fn by_name(name: &str) -> Option<ScenarioSpec> {
        Self::all().into_iter().find(|s| s.name == name)
    }

    /// The distinct visibility conditions this scenario can produce, in
    /// [`Visibility::ALL`] order (what the service layer prepares one
    /// conditioned network plan per).
    pub fn visibilities(&self) -> Vec<Visibility> {
        Visibility::ALL
            .iter()
            .copied()
            .filter(|&v| {
                self.phases
                    .iter()
                    .any(|p| p.condition_mix.iter().any(|&(pv, w)| pv == v && w > 0.0))
            })
            .collect()
    }

    /// A scripted generator running this scenario.
    pub fn generator(&self, seed: u64) -> SceneGenerator {
        let mut g = SceneGenerator::scripted(seed, self.phases.clone());
        g.mean_obstacles = self.mean_obstacles;
        g
    }
}

/// Streaming generator of scene frames.
#[derive(Debug, Clone)]
pub struct SceneGenerator {
    rng: Rng,
    next_id: u64,
    /// Mean obstacles per frame.
    pub mean_obstacles: f64,
    /// Condition mix: `(visibility, weight)`.
    pub condition_mix: Vec<(Visibility, f64)>,
    /// Obstacle-class mix. `None` keeps the legacy uniform draw — and
    /// its exact RNG consumption, so pre-scenario seeds stay
    /// bit-identical.
    pub class_mix: Option<Vec<(ObstacleClass, f64)>>,
    /// Scenario script, cycled by frame count (empty = static mixes).
    script: Vec<ScenarioPhase>,
    phase: usize,
    phase_left: usize,
}

impl SceneGenerator {
    /// Generator with the default day/night-heavy mix.
    pub fn new(seed: u64) -> Self {
        Self {
            rng: Rng::seeded(seed),
            next_id: 0,
            mean_obstacles: 3.0,
            condition_mix: vec![
                (Visibility::Day, 0.4),
                (Visibility::Night, 0.3),
                (Visibility::Fog, 0.1),
                (Visibility::Rain, 0.1),
                (Visibility::HarshLight, 0.1),
            ],
            class_mix: None,
            script: Vec::new(),
            phase: 0,
            phase_left: 0,
        }
    }

    /// Fix the generator to one condition (Fig. 4b per-column runs).
    pub fn with_condition(seed: u64, vis: Visibility) -> Self {
        let mut g = Self::new(seed);
        g.condition_mix = vec![(vis, 1.0)];
        g
    }

    /// Generator driven by a scenario script: each [`ScenarioPhase`]
    /// supplies the condition and class mixes for `phase.frames` frames,
    /// then the script advances (cycling back to the first phase). An
    /// empty script behaves exactly like [`Self::new`].
    pub fn scripted(seed: u64, phases: Vec<ScenarioPhase>) -> Self {
        let mut g = Self::new(seed);
        if let Some(first) = phases.first() {
            g.condition_mix = first.condition_mix.clone();
            g.class_mix = Some(first.class_mix.clone());
            g.phase_left = first.frames.max(1);
        }
        g.script = phases;
        g
    }

    fn sample_condition(&mut self) -> Visibility {
        let total: f64 = self.condition_mix.iter().map(|(_, w)| w).sum();
        let mut u = self.rng.f64() * total;
        for &(v, w) in &self.condition_mix {
            if u < w {
                return v;
            }
            u -= w;
        }
        self.condition_mix.last().map(|&(v, _)| v).unwrap_or(Visibility::Day)
    }

    fn sample_class(&mut self) -> ObstacleClass {
        let Some(mix) = &self.class_mix else {
            // The legacy uniform draw, RNG-identical to the
            // pre-scenario generator.
            return ObstacleClass::ALL[self.rng.below(ObstacleClass::ALL.len())];
        };
        let total: f64 = mix.iter().map(|(_, w)| w).sum();
        let mut u = self.rng.f64() * total;
        for &(c, w) in mix {
            if u < w {
                return c;
            }
            u -= w;
        }
        mix.last().map(|&(c, _)| c).unwrap_or(ObstacleClass::Pedestrian)
    }

    /// Advance the script at a phase boundary (no-op without a script).
    fn advance_script(&mut self) {
        if self.script.is_empty() {
            return;
        }
        if self.phase_left == 0 {
            self.phase = (self.phase + 1) % self.script.len();
            let ph = &self.script[self.phase];
            self.condition_mix = ph.condition_mix.clone();
            self.class_mix = Some(ph.class_mix.clone());
            self.phase_left = ph.frames.max(1);
        }
        self.phase_left -= 1;
    }

    /// Generate the next frame.
    pub fn next_frame(&mut self) -> SceneFrame {
        self.advance_script();
        let visibility = self.sample_condition();
        // Poisson-ish obstacle count via thinning (knuth for small mean).
        let mut n = 0usize;
        let l = (-self.mean_obstacles).exp();
        let mut p = 1.0;
        loop {
            p *= self.rng.f64();
            if p <= l {
                break;
            }
            n += 1;
        }
        let n = n.clamp(1, 8);
        let obstacles = (0..n)
            .map(|_| {
                let class = self.sample_class();
                Obstacle::sample(class, &mut self.rng)
            })
            .collect();
        let id = self.next_id;
        self.next_id += 1;
        SceneFrame { id, visibility, obstacles }
    }

    /// Generate `n` frames.
    pub fn frames(&mut self, n: usize) -> Vec<SceneFrame> {
        (0..n).map(|_| self.next_frame()).collect()
    }
}

/// The Fig. 3 route-planning scenario: a vehicle weighing a lane change.
///
/// Maps traffic context onto the inference operator's three inputs.
#[derive(Debug, Clone)]
pub struct LaneChangeScenario {
    /// Prior belief the cut-in is viable, from traffic context `P(A)`.
    pub prior_cut_in: f64,
    /// Probability of observing the target-lane evidence given the cut-in
    /// is viable, `P(B|A)`.
    pub evidence_given_viable: f64,
    /// Same evidence probability when the cut-in is not viable, `P(B|¬A)`.
    pub evidence_given_blocked: f64,
}

impl LaneChangeScenario {
    /// The paper's Fig. 3b instance (P(A)=57 %, P(B)≈72 %, posterior ≈61 %).
    pub fn fig3b() -> Self {
        Self {
            prior_cut_in: 0.57,
            evidence_given_viable: 0.77,
            evidence_given_blocked: 0.655,
        }
    }

    /// Randomised scenario for workload generation: prior from traffic
    /// density, likelihoods from sensor quality.
    pub fn sample(rng: &mut Rng) -> Self {
        let prior = rng.range_f64(0.2, 0.85);
        let quality = rng.range_f64(0.6, 0.95);
        Self {
            prior_cut_in: prior,
            evidence_given_viable: quality,
            evidence_given_blocked: (1.0 - quality) + rng.range_f64(0.0, 0.3),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generator_is_deterministic_per_seed() {
        let mut a = SceneGenerator::new(7);
        let mut b = SceneGenerator::new(7);
        let fa = a.next_frame();
        let fb = b.next_frame();
        assert_eq!(fa.obstacles.len(), fb.obstacles.len());
        assert_eq!(fa.visibility, fb.visibility);
        assert_eq!(fa.id, 0);
        assert_eq!(a.next_frame().id, 1);
    }

    #[test]
    fn frames_have_bounded_attributes() {
        let mut g = SceneGenerator::new(8);
        for f in g.frames(200) {
            assert!(!f.obstacles.is_empty() && f.obstacles.len() <= 8);
            for o in &f.obstacles {
                for v in [o.heat, o.contrast, o.distance, o.size] {
                    assert!((0.0..=1.0).contains(&v), "{o:?}");
                }
                let feats = o.features(f.visibility);
                assert!(feats.iter().all(|x| (0.0..=1.0).contains(x)));
            }
        }
    }

    #[test]
    fn condition_mix_respected() {
        let mut g = SceneGenerator::with_condition(9, Visibility::Night);
        assert!(g.frames(50).iter().all(|f| f.visibility == Visibility::Night));
    }

    #[test]
    fn class_signatures_separate_modal_failure_modes() {
        // Parked vehicles are cold but visible; pedestrians warm but lower
        // contrast — the complementarity fusion exploits.
        assert!(ObstacleClass::ParkedVehicle.heat() < 0.3);
        assert!(ObstacleClass::ParkedVehicle.contrast() > 0.6);
        assert!(ObstacleClass::Pedestrian.heat() > 0.8);
    }

    #[test]
    fn empty_script_matches_the_legacy_generator_bitwise() {
        // `scripted(seed, vec![])` must consume the RNG exactly like
        // `new(seed)` — the compatibility contract for existing seeds.
        let mut legacy = SceneGenerator::new(11);
        let mut scripted = SceneGenerator::scripted(11, Vec::new());
        for _ in 0..50 {
            let a = legacy.next_frame();
            let b = scripted.next_frame();
            assert_eq!(a.visibility, b.visibility);
            assert_eq!(a.obstacles.len(), b.obstacles.len());
            for (oa, ob) in a.obstacles.iter().zip(&b.obstacles) {
                assert_eq!(oa.class, ob.class);
                assert_eq!(oa.heat.to_bits(), ob.heat.to_bits());
                assert_eq!(oa.distance.to_bits(), ob.distance.to_bits());
            }
        }
    }

    #[test]
    fn glare_burst_script_cycles_its_phases() {
        let mut g = ScenarioSpec::glare_burst().generator(12);
        // Phase 1: 16 clear-day frames; phase 2: 8 harsh-light frames;
        // then the script cycles.
        for i in 0..48 {
            let f = g.next_frame();
            let expect = if i % 24 < 16 { Visibility::Day } else { Visibility::HarshLight };
            assert_eq!(f.visibility, expect, "frame {i}");
        }
    }

    #[test]
    fn visibility_sweep_covers_all_conditions() {
        let spec = ScenarioSpec::visibility_sweep();
        assert_eq!(spec.visibilities(), Visibility::ALL.to_vec());
        let mut g = spec.generator(13);
        let mut seen = [false; 5];
        for _ in 0..60 {
            let f = g.next_frame();
            let i = Visibility::ALL.iter().position(|&v| v == f.visibility).unwrap();
            seen[i] = true;
        }
        assert!(seen.iter().all(|&s| s), "one 60-frame cycle must visit every condition");
    }

    #[test]
    fn class_mix_skews_the_obstacle_population() {
        let mut g = ScenarioSpec::night_pedestrians().generator(14);
        let mut ped = 0usize;
        let mut total = 0usize;
        for _ in 0..200 {
            for o in g.next_frame().obstacles {
                total += 1;
                ped += (o.class == ObstacleClass::Pedestrian) as usize;
            }
        }
        let frac = ped as f64 / total as f64;
        assert!(frac > 0.4, "pedestrian-heavy mix produced only {frac:.2} pedestrians");
    }

    #[test]
    fn tracked_variants_share_their_base_world() {
        let base = ScenarioSpec::foggy_highway();
        let tracked = ScenarioSpec::tracked_foggy_highway();
        assert!(tracked.is_tracked() && !base.is_tracked());
        assert_eq!(tracked.phases.len(), base.phases.len());
        assert_eq!(tracked.visibilities(), base.visibilities());
        assert_eq!(tracked.mean_obstacles, base.mean_obstacles);
        // Same seed, same script → bit-identical worlds: tracking changes
        // how frames are consumed, never what happens in them.
        let mut a = base.generator(33);
        let mut b = tracked.generator(33);
        for _ in 0..20 {
            let (fa, fb) = (a.next_frame(), b.next_frame());
            assert_eq!(fa.visibility, fb.visibility);
            assert_eq!(fa.obstacles.len(), fb.obstacles.len());
        }
        assert_eq!(ScenarioSpec::all().iter().filter(|s| s.is_tracked()).count(), 3);
    }

    #[test]
    fn scenario_registry_round_trips() {
        let all = ScenarioSpec::all();
        assert!(all.len() >= 8);
        for s in &all {
            let found = ScenarioSpec::by_name(s.name).unwrap();
            assert_eq!(found.name, s.name);
            assert!(!s.phases.is_empty());
            assert!(!s.visibilities().is_empty());
            for ph in &s.phases {
                let w: f64 = ph.class_mix.iter().map(|(_, w)| w).sum();
                assert!(w > 0.0, "{}: degenerate class mix", s.name);
            }
        }
        assert!(ScenarioSpec::by_name("no-such-scenario").is_none());
        // Scenario names restricted to a single condition really stick.
        let mut g = ScenarioSpec::night_pedestrians().generator(15);
        assert!((0..30).all(|_| g.next_frame().visibility == Visibility::Night));
    }

    #[test]
    fn fig3b_scenario_matches_paper_constants() {
        let s = LaneChangeScenario::fig3b();
        let pb = s.prior_cut_in * s.evidence_given_viable
            + (1.0 - s.prior_cut_in) * s.evidence_given_blocked;
        assert!((pb - 0.72).abs() < 0.005);
        let mut rng = Rng::seeded(1);
        for _ in 0..100 {
            let r = LaneChangeScenario::sample(&mut rng);
            assert!((0.0..=1.0).contains(&r.prior_cut_in));
            assert!((0.0..=1.0).contains(&r.evidence_given_viable));
            assert!((0.0..=1.0).contains(&r.evidence_given_blocked));
        }
    }
}
