//! Recursive Bayesian filtering over scene frames — the consumption
//! model behind the `tracked-*` scenario family.
//!
//! The streaming [`super::pipeline`] decides every frame independently:
//! each decision sees the scenario network's **baked** hazard prior and
//! forgets everything the previous frame established. This module closes
//! the loop instead. Per frame it serves `P(hazard | alert)` through a
//! prepared plan whose hazard prior is **rebound per decision** — a
//! [`crate::coordinator::NetworkOverride`] on `("hazard", row 0)`
//! carrying the previous frame's served posterior, quantized onto the
//! binding grid and saturation-clamped away from 0/1:
//!
//! ```text
//!  frame t ──► alert_t (any candidate box?) ──► decide on plan(vis_t, alert_t)
//!                 │                                  │ prior override =
//!                 │                                  │ clamp(quantize(b_{t-1}))
//!                 ▼                                  ▼
//!           forward reference (VE)            b_t = served posterior ──► t+1
//! ```
//!
//! That is a discrete-time HMM forward pass (measurement update only —
//! the quantize/clamp transform *is* the binding-side transition): the
//! textbook recursive filter, realised on the fixed-structure /
//! rebindable-probability split of the memristor Bayesian machine. No
//! plan is prepared after warm-up — every per-frame decide is a pure
//! binding against the plans prepared up front, so the plan cache sees
//! **zero misses once streaming starts**. Warm-up itself exercises the
//! rebind path: the per-visibility scenario networks differ only in
//! their CPT values, so one compile serves every condition.
//!
//! The acceptance fold compares three chains per run:
//! - **served**: the hardware posterior fed back through the binding —
//!   what the filter actually believes;
//! - **reference**: a closed-form forward algorithm (variable
//!   elimination on a freshly built net per frame) running the *same*
//!   quantize/clamp recursion on its own exact posteriors — it never
//!   sees a hardware value ([`TrackerReport::mae_vs_reference`]);
//! - **baseline**: per-frame independent decisions from the baked prior
//!   — what the pipeline would have believed. The
//!   [`TrackerReport::track_continuity_gain`] measures how much longer
//!   the filter holds a hazard through evidence dropouts than the
//!   memoryless baseline.

use std::sync::Arc;

use crate::config::AppConfig;
use crate::coordinator::{
    Coordinator, DecisionParams, MetricsSnapshot, NetworkOverride, PlanHandle, PlanSpec, Policy,
};
use crate::network::exact_posterior_by_name;
use crate::{Error, Result};

use super::detector::fusion_input;
use super::pipeline::{scenario_network, scenario_network_with_prior, HAZARD_BAKED_PRIOR};
use super::{ScenarioSpec, VideoWorkload, Visibility};

/// How a tracked scene run is served.
#[derive(Debug, Clone)]
pub struct TrackerConfig {
    /// The scenario script to track (any scenario works; the `tracked-*`
    /// registry family exists to route the CLI here).
    pub scenario: ScenarioSpec,
    /// Frames to filter over.
    pub frames: usize,
    /// Master seed (scene generator + detector noise + worker bank).
    pub seed: u64,
    /// Stochastic stream length per decision. The acceptance operating
    /// point is 2^14 bits (per-decision error ≈ 0.004, well inside the
    /// 0.03 reference budget even after feedback).
    pub bits: usize,
    /// Detection threshold for the continuity metric.
    pub threshold: f64,
    /// Binding grid: priors are rounded to multiples of this before
    /// being rebound (the finite write resolution of a memristor
    /// conductance — ~10 bits here).
    pub quantum: f64,
    /// Saturation clamp: the rebound prior never leaves
    /// `[floor, ceil]`. Certainty is absorbing under Bayes — a belief
    /// that reaches exactly 0/1 could never recover from a bad frame.
    pub prior_floor: f64,
    /// Upper saturation clamp (see [`Self::prior_floor`]).
    pub prior_ceil: f64,
}

impl Default for TrackerConfig {
    fn default() -> Self {
        Self {
            scenario: ScenarioSpec::tracked_foggy_highway(),
            frames: 64,
            seed: 42,
            bits: 1 << 14,
            threshold: 0.5,
            quantum: 1.0 / 1024.0,
            prior_floor: 0.02,
            prior_ceil: 0.98,
        }
    }
}

impl TrackerConfig {
    /// Config for a scenario at the acceptance operating point.
    pub fn for_scenario(scenario: ScenarioSpec, frames: usize, seed: u64) -> Self {
        Self { scenario, frames, seed, ..Self::default() }
    }

    /// Quantize a served posterior onto the binding grid and clamp it
    /// away from the absorbing 0/1 — the posterior→prior transform of
    /// the recursion. Pure and total: the reference chain applies the
    /// identical function to its own exact posteriors.
    pub fn bind_prior(&self, posterior: f64) -> f64 {
        let q = (posterior / self.quantum).round() * self.quantum;
        q.clamp(self.prior_floor, self.prior_ceil)
    }

    fn validate(&self) -> Result<()> {
        if self.frames == 0 {
            return Err(Error::Config("tracker.frames must be > 0".into()));
        }
        if !(0.0..=1.0).contains(&self.threshold) {
            return Err(Error::Config(format!(
                "tracker.threshold must be a probability, got {}",
                self.threshold
            )));
        }
        if !(self.quantum > 0.0 && self.quantum <= 0.25) {
            return Err(Error::Config(format!(
                "tracker.quantum must lie in (0, 0.25], got {}",
                self.quantum
            )));
        }
        if !(0.0 < self.prior_floor && self.prior_floor < self.prior_ceil && self.prior_ceil < 1.0)
        {
            return Err(Error::Config(format!(
                "tracker clamp must satisfy 0 < floor < ceil < 1, got [{}, {}]",
                self.prior_floor, self.prior_ceil
            )));
        }
        Ok(())
    }
}

/// One frame of the filtering run: every chain's view of it.
#[derive(Debug, Clone)]
pub struct TrackStep {
    /// Frame index.
    pub frame: usize,
    /// Ambient condition the frame was generated under.
    pub visibility: Visibility,
    /// The frame's measurement: did any detector head propose a box?
    pub alert: bool,
    /// The prior actually rebound for this decision
    /// (`bind_prior(previous served posterior)`).
    pub prior: f64,
    /// Hardware posterior served through the plan.
    pub posterior: f64,
    /// Closed-form posterior of the *served* decision (VE under the same
    /// override — the per-decision `exact` the plan layer reports).
    pub exact: f64,
    /// Forward-algorithm reference chain (never sees hardware values).
    pub reference: f64,
    /// Memoryless baseline: the same frame decided from the baked prior.
    pub baseline: f64,
}

/// What a tracked run measured.
#[derive(Debug, Clone)]
pub struct TrackerReport {
    /// Scenario name.
    pub scenario: String,
    /// Frames filtered.
    pub frames: usize,
    /// Stream length used per decision.
    pub bits: usize,
    /// Per-frame record of every chain.
    pub steps: Vec<TrackStep>,
    /// Mean `|served − reference|` over the run — the acceptance number
    /// (≤ 0.03 at 2^14 bits).
    pub mae_vs_reference: f64,
    /// Fraction of frames the *filtered* belief held above threshold.
    pub track_continuity: f64,
    /// Fraction of frames the memoryless baseline held above threshold.
    pub baseline_continuity: f64,
    /// Coordinator metrics at the end of the run (plan-cache accounting:
    /// all misses/rebinds happen in warm-up, none while streaming).
    pub snapshot: MetricsSnapshot,
    /// Plans prepared during warm-up (distinct `(visibility, alert)`
    /// conditions) — the whole cache traffic of the run.
    pub plans_prepared: usize,
}

impl TrackerReport {
    /// How much longer the filter holds a hazard than per-frame
    /// decisions: `track_continuity − baseline_continuity`. Positive
    /// whenever evidence dropouts are shorter than the belief's decay —
    /// the value of carrying state across frames.
    pub fn track_continuity_gain(&self) -> f64 {
        self.track_continuity - self.baseline_continuity
    }

    /// Render a compact text report.
    pub fn to_table(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "tracked scenario '{}': {} frames at {} bits, {} plans warm\n",
            self.scenario, self.frames, self.bits, self.plans_prepared
        ));
        out.push_str(&format!(
            "belief chain         mae vs forward reference {:.4} (budget 0.03)\n",
            self.mae_vs_reference
        ));
        out.push_str(&format!(
            "track continuity     {:.3} filtered vs {:.3} per-frame baseline ({:+.3} gain)\n",
            self.track_continuity,
            self.baseline_continuity,
            self.track_continuity_gain()
        ));
        out.push_str(&format!(
            "plan cache           {} hits / {} misses / {} rebinds after warmup\n",
            self.snapshot.plan_hits, self.snapshot.plan_misses, self.snapshot.plan_rebinds
        ));
        let alerts = self.steps.iter().filter(|s| s.alert).count();
        out.push_str(&format!(
            "measurements         {} alert frames / {} quiet frames\n",
            alerts,
            self.frames - alerts
        ));
        out
    }
}

/// The per-condition plan table: one prepared plan per distinct
/// `(visibility, alert polarity)` the scenario can produce. Evidence is
/// part of a plan's structure (it is compiled into the netlist), so the
/// two polarities need two structures; visibilities within a polarity
/// differ only in CPT values and share one compile via rebinds.
struct PlanTable {
    plans: Vec<(Visibility, bool, PlanHandle)>,
}

impl PlanTable {
    fn get(&self, vis: Visibility, alert: bool) -> Result<&PlanHandle> {
        self.plans
            .iter()
            .find(|&&(v, a, _)| v == vis && a == alert)
            .map(|(_, _, p)| p)
            .ok_or_else(|| {
                Error::Coordinator(format!("no tracker plan for ({vis:?}, alert={alert})"))
            })
    }
}

/// Filter `config.frames` scenario frames through per-decision prior
/// rebinding and report the three-chain comparison. Deterministic for a
/// given config: the run uses one coordinator worker and the recursion
/// forces sequential decides, so same seed ⇒ bit-identical report.
pub fn run(config: &TrackerConfig) -> Result<TrackerReport> {
    config.validate()?;
    let mut app = AppConfig { seed: config.seed, ..AppConfig::default() };
    app.sne.n_bits = config.bits;
    // One worker, full sweeps, no deadline: the belief recursion is
    // inherently sequential (frame t+1's binding needs frame t's
    // posterior), so extra workers buy nothing and cost reproducibility.
    app.coordinator.workers = 1;
    let coord = Coordinator::start(&app)?;
    let handle = coord.handle();
    let policy = Policy::default();

    // Warm-up: prepare every (visibility, alert) plan the scenario can
    // need. This is the run's entire plan-cache traffic — the frame
    // loop only ever *binds* against these handles.
    let mut plans = Vec::new();
    for alert in [true, false] {
        for vis in config.scenario.visibilities() {
            let plan = handle
                .prepare(PlanSpec::Network {
                    net: Arc::new(scenario_network(vis)),
                    query: "hazard".into(),
                    evidence: vec![("alert".into(), alert)],
                })?
                .with_policy(policy);
            plans.push((vis, alert, plan));
        }
    }
    let table = PlanTable { plans };
    let plans_prepared = table.plans.len();

    // Memoryless baseline posteriors, one per (visibility, alert).
    let mut baselines: Vec<(Visibility, bool, f64)> = Vec::new();
    for alert in [true, false] {
        for vis in config.scenario.visibilities() {
            let (p, _) =
                exact_posterior_by_name(&scenario_network(vis), "hazard", &[("alert", alert)])?;
            baselines.push((vis, alert, p));
        }
    }

    let mut workload =
        VideoWorkload::with_generator(config.scenario.generator(config.seed), config.seed);

    let mut belief_served = HAZARD_BAKED_PRIOR;
    let mut belief_reference = HAZARD_BAKED_PRIOR;
    let mut steps = Vec::with_capacity(config.frames);
    let mut abs_err_sum = 0.0;
    let (mut held, mut base_held) = (0usize, 0usize);
    for frame in 0..config.frames {
        let det = workload.next_detections();
        let vis = det.frame.visibility;
        // The frame's binary measurement: did either head propose a
        // candidate box on any obstacle? (Same Ref-31 semantics as the
        // pipeline's fusion submissions.)
        let alert = det
            .confidences
            .iter()
            .any(|&(rgb, th)| fusion_input(rgb) > 0.5 || fusion_input(th) > 0.5);

        // Served chain: rebind the previous posterior as this frame's
        // prior — one override, zero recompile.
        let prior = config.bind_prior(belief_served);
        let d = table.get(vis, alert)?.decide(DecisionParams::Network {
            overrides: vec![NetworkOverride::new("hazard", 0, prior)],
        })?;
        belief_served = d.posterior;

        // Reference chain: the same recursion in closed form, on its own
        // exact posteriors.
        let prior_ref = config.bind_prior(belief_reference);
        let (reference, _) = exact_posterior_by_name(
            &scenario_network_with_prior(vis, prior_ref),
            "hazard",
            &[("alert", alert)],
        )?;
        belief_reference = reference;

        let baseline = baselines
            .iter()
            .find(|&&(v, a, _)| v == vis && a == alert)
            .map(|&(_, _, p)| p)
            .unwrap_or(HAZARD_BAKED_PRIOR);

        abs_err_sum += (d.posterior - reference).abs();
        held += (d.posterior >= config.threshold) as usize;
        base_held += (baseline >= config.threshold) as usize;
        steps.push(TrackStep {
            frame,
            visibility: vis,
            alert,
            prior,
            posterior: d.posterior,
            exact: d.exact,
            reference,
            baseline,
        });
    }

    let snapshot = handle.metrics().snapshot();
    coord.shutdown();
    let n = config.frames as f64;
    Ok(TrackerReport {
        scenario: config.scenario.name.to_string(),
        frames: config.frames,
        bits: config.bits,
        steps,
        mae_vs_reference: abs_err_sum / n,
        track_continuity: held as f64 / n,
        baseline_continuity: base_held as f64 / n,
        snapshot,
        plans_prepared,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_config(seed: u64) -> TrackerConfig {
        TrackerConfig {
            scenario: ScenarioSpec::tracked_foggy_highway(),
            frames: 24,
            seed,
            bits: 4096,
            ..TrackerConfig::default()
        }
    }

    #[test]
    fn bind_prior_quantizes_and_clamps() {
        let c = TrackerConfig::default();
        // On-grid values round-trip.
        assert_eq!(c.bind_prior(0.5), 0.5);
        // Off-grid values land on a multiple of the quantum.
        let q = c.bind_prior(0.123456789);
        assert!((q / c.quantum - (q / c.quantum).round()).abs() < 1e-9);
        assert!((q - 0.123456789).abs() <= c.quantum / 2.0 + 1e-12);
        // Saturation: certainty never reaches the absorbing 0/1.
        assert_eq!(c.bind_prior(1.0), c.prior_ceil);
        assert_eq!(c.bind_prior(0.0), c.prior_floor);
        assert_eq!(c.bind_prior(0.999), c.prior_ceil);
    }

    #[test]
    fn config_validation_rejects_degenerate_trackers() {
        assert!(TrackerConfig { frames: 0, ..TrackerConfig::default() }.validate().is_err());
        assert!(TrackerConfig { quantum: 0.0, ..TrackerConfig::default() }.validate().is_err());
        assert!(TrackerConfig { threshold: 1.5, ..TrackerConfig::default() }.validate().is_err());
        let inverted = TrackerConfig {
            prior_floor: 0.9,
            prior_ceil: 0.1,
            ..TrackerConfig::default()
        };
        assert!(inverted.validate().is_err());
        assert!(TrackerConfig::default().validate().is_ok());
    }

    #[test]
    fn tracked_run_stays_near_the_forward_reference() {
        // The acceptance fold at the 2^14-bit operating point: the
        // served belief chain tracks the closed-form forward algorithm
        // within 0.03 MAE even though errors feed back through the
        // prior binding.
        let cfg = TrackerConfig::for_scenario(ScenarioSpec::tracked_foggy_highway(), 48, 7);
        let r = run(&cfg).unwrap();
        assert_eq!(r.steps.len(), 48);
        assert!(
            r.mae_vs_reference <= 0.03,
            "served chain drifted from the forward reference: MAE {}",
            r.mae_vs_reference
        );
        for s in &r.steps {
            assert!((0.0..=1.0).contains(&s.posterior), "frame {}: {s:?}", s.frame);
            // The bound prior respects the grid and the clamp.
            assert!(s.prior >= cfg.prior_floor && s.prior <= cfg.prior_ceil);
            // The served per-decision exact is the same closed form the
            // reference computes — they only differ through the chains'
            // different priors.
            assert!((0.0..=1.0).contains(&s.exact));
        }
        let table = r.to_table();
        assert!(table.contains("tracked-foggy-highway"), "{table}");
        assert!(table.contains("mae vs forward reference"), "{table}");
    }

    #[test]
    fn warmup_is_the_only_cache_traffic_and_rebinds_share_compiles() {
        let cfg = small_config(11);
        let r = run(&cfg).unwrap();
        let s = &r.snapshot;
        // Every prepare happened in warm-up: the frame loop is pure
        // binding, so cache traffic equals the prepared-plan count —
        // zero misses (or rebinds) after warmup.
        assert_eq!(
            s.plan_misses + s.plan_rebinds,
            r.plans_prepared as u64,
            "frame loop leaked plan-cache traffic: {s:?}"
        );
        assert_eq!(s.plan_hits, 0, "tracker prepares each distinct plan exactly once");
        // The per-visibility nets share structure: one compile (miss)
        // per evidence polarity, everything else rebinds.
        assert_eq!(s.plan_misses, 2, "expected one compile per alert polarity: {s:?}");
        assert_eq!(s.plan_rebinds, r.plans_prepared as u64 - 2);
        // fog + rain, two polarities each.
        assert_eq!(r.plans_prepared, 4);
    }

    #[test]
    fn filtering_holds_belief_through_evidence_dropouts() {
        // The point of the recursion: on an alert-heavy scenario with
        // isolated quiet frames, the filtered belief outlasts the
        // memoryless baseline (which collapses on every dropout).
        let cfg = TrackerConfig::for_scenario(ScenarioSpec::tracked_foggy_highway(), 96, 5);
        let r = run(&cfg).unwrap();
        assert!(
            r.track_continuity >= r.baseline_continuity,
            "filtering lost continuity: {} vs {}",
            r.track_continuity,
            r.baseline_continuity
        );
        // The reference chain agrees about the shape (not just the
        // hardware chain being lucky).
        let ref_held = r.steps.iter().filter(|s| s.reference >= cfg.threshold).count();
        let base_held = r.steps.iter().filter(|s| s.baseline >= cfg.threshold).count();
        assert!(ref_held >= base_held, "reference chain disagrees: {ref_held} < {base_held}");
    }

    #[test]
    fn tracked_run_is_bit_reproducible_per_seed() {
        // Two runs on the same seed: every chain bit-identical, frame by
        // frame — the determinism contract the CLI and bench rely on.
        let a = run(&small_config(21)).unwrap();
        let b = run(&small_config(21)).unwrap();
        assert_eq!(a.steps.len(), b.steps.len());
        for (x, y) in a.steps.iter().zip(&b.steps) {
            assert_eq!(x.posterior.to_bits(), y.posterior.to_bits(), "frame {}", x.frame);
            assert_eq!(x.prior.to_bits(), y.prior.to_bits(), "frame {}", x.frame);
            assert_eq!(x.reference.to_bits(), y.reference.to_bits(), "frame {}", x.frame);
            assert_eq!(x.alert, y.alert, "frame {}", x.frame);
        }
        assert_eq!(a.mae_vs_reference.to_bits(), b.mae_vs_reference.to_bits());
        // A different seed produces a genuinely different run.
        let c = run(&small_config(22)).unwrap();
        let same = a
            .steps
            .iter()
            .zip(&c.steps)
            .all(|(x, y)| x.posterior.to_bits() == y.posterior.to_bits());
        assert!(!same, "seed 21 and 22 produced identical belief chains");
    }

    #[test]
    fn baked_prior_round_trips_through_the_override_path() {
        // Frame 0 binds bind_prior(HAZARD_BAKED_PRIOR): the override
        // machinery must reproduce what the baked net computes for the
        // same (quantized) prior — checked against the closed form.
        let cfg = small_config(3);
        let r = run(&cfg).unwrap();
        let first = &r.steps[0];
        let (expect, _) = exact_posterior_by_name(
            &scenario_network_with_prior(first.visibility, first.prior),
            "hazard",
            &[("alert", first.alert)],
        )
        .unwrap();
        assert!(
            (first.exact - expect).abs() < 1e-12,
            "served exact {} vs closed form {expect}",
            first.exact
        );
        assert_eq!(first.reference.to_bits(), expect.to_bits(), "chains share frame 0");
    }
}
