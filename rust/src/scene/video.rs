//! Video workload — the Movie S1 large-scale fusion experiment: a stream
//! of frames, per-obstacle single-modal detections, and the aggregate
//! detection-rate statistics the paper quotes (fusion finds +85 % more
//! obstacles than thermal-only and +19 % more than RGB-only).

use crate::bayes::exact_fusion;
use crate::util::Rng;

use super::detector::fusion_input;
use super::{DetectorModel, Modality, SceneFrame, SceneGenerator};

/// Detections for every ground-truth obstacle of one frame.
#[derive(Debug, Clone)]
pub struct FrameDetections {
    /// The underlying frame.
    pub frame: SceneFrame,
    /// Per-obstacle `(P(y|x_RGB), P(y|x_thermal))`.
    pub confidences: Vec<(f64, f64)>,
}

/// Aggregate detection statistics over a video run.
#[derive(Debug, Clone, Default)]
pub struct VideoStats {
    /// Ground-truth obstacles seen.
    pub obstacles: usize,
    /// Frames processed.
    pub frames: usize,
    /// RGB-only detections (confidence > threshold).
    pub rgb_detections: usize,
    /// Thermal-only detections.
    pub thermal_detections: usize,
    /// Fused detections (closed-form fusion > threshold).
    pub fused_detections: usize,
    /// Sum of RGB confidences (for mean confidence).
    pub rgb_conf_sum: f64,
    /// Sum of thermal confidences.
    pub thermal_conf_sum: f64,
    /// Sum of fused confidences.
    pub fused_conf_sum: f64,
}

impl VideoStats {
    /// Detection rate of a modality.
    pub fn rate(&self, hits: usize) -> f64 {
        if self.obstacles == 0 {
            0.0
        } else {
            hits as f64 / self.obstacles as f64
        }
    }

    /// Fusion detection-rate improvement over thermal-only (paper: +85 %).
    pub fn gain_vs_thermal(&self) -> f64 {
        if self.thermal_detections == 0 {
            0.0
        } else {
            self.fused_detections as f64 / self.thermal_detections as f64 - 1.0
        }
    }

    /// Fusion detection-rate improvement over RGB-only (paper: +19 %).
    pub fn gain_vs_rgb(&self) -> f64 {
        if self.rgb_detections == 0 {
            0.0
        } else {
            self.fused_detections as f64 / self.rgb_detections as f64 - 1.0
        }
    }

    /// Mean fused confidence on detected obstacles vs best single modal —
    /// the paper's "decisions at a higher confidence".
    pub fn mean_confidences(&self) -> (f64, f64, f64) {
        let n = self.obstacles.max(1) as f64;
        (self.rgb_conf_sum / n, self.thermal_conf_sum / n, self.fused_conf_sum / n)
    }
}

/// A video workload: scene generator + detector pair + detection RNG.
pub struct VideoWorkload {
    generator: SceneGenerator,
    rgb: DetectorModel,
    thermal: DetectorModel,
    rng: Rng,
    /// Detection threshold used for the rate statistics.
    pub threshold: f64,
}

impl VideoWorkload {
    /// Workload over the default scene mix.
    pub fn new(seed: u64) -> Self {
        Self {
            generator: SceneGenerator::new(seed),
            rgb: DetectorModel::new(Modality::Rgb),
            thermal: DetectorModel::new(Modality::Thermal),
            rng: Rng::seeded(seed ^ 0x5EED),
            threshold: 0.5,
        }
    }

    /// Workload from a custom generator.
    pub fn with_generator(generator: SceneGenerator, seed: u64) -> Self {
        Self {
            generator,
            rgb: DetectorModel::new(Modality::Rgb),
            thermal: DetectorModel::new(Modality::Thermal),
            rng: Rng::seeded(seed ^ 0x5EED),
            threshold: 0.5,
        }
    }

    /// Produce the next frame's detections.
    pub fn next_detections(&mut self) -> FrameDetections {
        let frame = self.generator.next_frame();
        let confidences = frame
            .obstacles
            .iter()
            .map(|o| {
                (
                    self.rgb.detect(o, frame.visibility, &mut self.rng),
                    self.thermal.detect(o, frame.visibility, &mut self.rng),
                )
            })
            .collect();
        FrameDetections { frame, confidences }
    }

    /// Run `n_frames`, folding detections into aggregate statistics using
    /// closed-form fusion (the stochastic-hardware path is exercised by
    /// the coordinator benches; this is the workload-level oracle).
    pub fn run(&mut self, n_frames: usize) -> VideoStats {
        let mut stats = VideoStats::default();
        for _ in 0..n_frames {
            let det = self.next_detections();
            stats.frames += 1;
            for &(p_rgb, p_th) in &det.confidences {
                // Ref-31 ensembling: misses contribute the prior, so a
                // blind modality cannot veto the other.
                let fused = exact_fusion(fusion_input(p_rgb), fusion_input(p_th));
                stats.obstacles += 1;
                stats.rgb_conf_sum += p_rgb;
                stats.thermal_conf_sum += p_th;
                stats.fused_conf_sum += fused;
                if p_rgb > self.threshold {
                    stats.rgb_detections += 1;
                }
                if p_th > self.threshold {
                    stats.thermal_detections += 1;
                }
                if fused > self.threshold {
                    stats.fused_detections += 1;
                }
            }
        }
        stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn movie_s1_gains_have_paper_shape() {
        let mut wl = VideoWorkload::new(80);
        let stats = wl.run(1_000);
        assert!(stats.obstacles > 1_000);
        let g_th = stats.gain_vs_thermal();
        let g_rgb = stats.gain_vs_rgb();
        // Paper: +85 % vs thermal, +19 % vs RGB. Shape requirement: fusion
        // dominates both, with the thermal gain much larger.
        assert!(g_th > 0.55 && g_th < 1.2, "thermal gain {g_th}");
        assert!(g_rgb > 0.08 && g_rgb < 0.35, "rgb gain {g_rgb}");
        assert!(g_th > g_rgb * 2.0);
    }

    #[test]
    fn fusion_raises_mean_confidence() {
        let mut wl = VideoWorkload::new(81);
        let stats = wl.run(400);
        let (rgb, th, fused) = stats.mean_confidences();
        assert!(fused > rgb && fused > th, "fused {fused} vs rgb {rgb}, th {th}");
    }

    #[test]
    fn detections_align_with_obstacles() {
        let mut wl = VideoWorkload::new(82);
        for _ in 0..20 {
            let d = wl.next_detections();
            assert_eq!(d.confidences.len(), d.frame.obstacles.len());
            for &(a, b) in &d.confidences {
                assert!((0.0..=1.0).contains(&a) && (0.0..=1.0).contains(&b));
            }
        }
    }

    #[test]
    fn empty_stats_are_safe() {
        let s = VideoStats::default();
        assert_eq!(s.rate(0), 0.0);
        assert_eq!(s.gain_vs_thermal(), 0.0);
        assert_eq!(s.gain_vs_rgb(), 0.0);
    }
}
