//! Video workload — the Movie S1 large-scale fusion experiment: a stream
//! of frames, per-obstacle single-modal detections, and the aggregate
//! detection-rate statistics the paper quotes (fusion finds +85 % more
//! obstacles than thermal-only and +19 % more than RGB-only).

use crate::bayes::exact_fusion;
use crate::util::Rng;

use super::detector::fusion_input;
use super::{DetectorModel, Modality, SceneFrame, SceneGenerator};

/// Detections for every ground-truth obstacle of one frame.
#[derive(Debug, Clone)]
pub struct FrameDetections {
    /// The underlying frame.
    pub frame: SceneFrame,
    /// Per-obstacle `(P(y|x_RGB), P(y|x_thermal))`.
    pub confidences: Vec<(f64, f64)>,
}

/// Aggregate detection statistics over a video run.
#[derive(Debug, Clone, Default)]
pub struct VideoStats {
    /// Ground-truth obstacles seen.
    pub obstacles: usize,
    /// Frames processed.
    pub frames: usize,
    /// RGB-only detections (confidence > threshold).
    pub rgb_detections: usize,
    /// Thermal-only detections.
    pub thermal_detections: usize,
    /// Fused detections (closed-form fusion > threshold).
    pub fused_detections: usize,
    /// Sum of RGB confidences (for mean confidence).
    pub rgb_conf_sum: f64,
    /// Sum of thermal confidences.
    pub thermal_conf_sum: f64,
    /// Sum of fused confidences.
    pub fused_conf_sum: f64,
}

impl VideoStats {
    /// Fold one obstacle's per-modality confidences and fused posterior
    /// into the counters. Shared by the oracle fold
    /// ([`VideoWorkload::run`]) and the hardware fold
    /// ([`super::pipeline`]), so the two paths can never drift on what
    /// counts as a detection.
    pub fn record(&mut self, rgb_conf: f64, thermal_conf: f64, fused_conf: f64, threshold: f64) {
        self.obstacles += 1;
        self.rgb_conf_sum += rgb_conf;
        self.thermal_conf_sum += thermal_conf;
        self.fused_conf_sum += fused_conf;
        if rgb_conf > threshold {
            self.rgb_detections += 1;
        }
        if thermal_conf > threshold {
            self.thermal_detections += 1;
        }
        if fused_conf > threshold {
            self.fused_detections += 1;
        }
    }

    /// Detection rate of a modality.
    pub fn rate(&self, hits: usize) -> f64 {
        if self.obstacles == 0 {
            0.0
        } else {
            hits as f64 / self.obstacles as f64
        }
    }

    /// Gain of fused detections over a single-modal baseline. A zero
    /// baseline with fused detections present is **infinite** gain —
    /// exactly the night/glare regimes where one sensor is blind and
    /// fusion recovers everything (the old `0.0` return reported "no
    /// gain" there). `0.0` only when both counts are zero.
    fn gain(fused: usize, baseline: usize) -> f64 {
        if baseline == 0 {
            if fused == 0 {
                0.0
            } else {
                f64::INFINITY
            }
        } else {
            fused as f64 / baseline as f64 - 1.0
        }
    }

    /// Fusion detection-rate improvement over thermal-only (paper:
    /// +85 %). [`f64::INFINITY`] when fusion detects over a blind
    /// thermal baseline.
    pub fn gain_vs_thermal(&self) -> f64 {
        Self::gain(self.fused_detections, self.thermal_detections)
    }

    /// Fusion detection-rate improvement over RGB-only (paper: +19 %).
    /// [`f64::INFINITY`] when fusion detects over a blind RGB baseline
    /// (night/glare).
    pub fn gain_vs_rgb(&self) -> f64 {
        Self::gain(self.fused_detections, self.rgb_detections)
    }

    /// Mean fused confidence on detected obstacles vs best single modal —
    /// the paper's "decisions at a higher confidence".
    pub fn mean_confidences(&self) -> (f64, f64, f64) {
        let n = self.obstacles.max(1) as f64;
        (self.rgb_conf_sum / n, self.thermal_conf_sum / n, self.fused_conf_sum / n)
    }
}

/// A video workload: scene generator + detector pair + detection RNG.
pub struct VideoWorkload {
    generator: SceneGenerator,
    rgb: DetectorModel,
    thermal: DetectorModel,
    rng: Rng,
    /// Detection threshold used for the rate statistics.
    pub threshold: f64,
}

impl VideoWorkload {
    /// Workload over the default scene mix.
    pub fn new(seed: u64) -> Self {
        Self {
            generator: SceneGenerator::new(seed),
            rgb: DetectorModel::new(Modality::Rgb),
            thermal: DetectorModel::new(Modality::Thermal),
            rng: Rng::seeded(seed ^ 0x5EED),
            threshold: 0.5,
        }
    }

    /// Workload from a custom generator.
    pub fn with_generator(generator: SceneGenerator, seed: u64) -> Self {
        Self {
            generator,
            rgb: DetectorModel::new(Modality::Rgb),
            thermal: DetectorModel::new(Modality::Thermal),
            rng: Rng::seeded(seed ^ 0x5EED),
            threshold: 0.5,
        }
    }

    /// Produce the next frame's detections.
    pub fn next_detections(&mut self) -> FrameDetections {
        let frame = self.generator.next_frame();
        let confidences = frame
            .obstacles
            .iter()
            .map(|o| {
                (
                    self.rgb.detect(o, frame.visibility, &mut self.rng),
                    self.thermal.detect(o, frame.visibility, &mut self.rng),
                )
            })
            .collect();
        FrameDetections { frame, confidences }
    }

    /// Run `n_frames`, folding detections into aggregate statistics using
    /// closed-form fusion.
    ///
    /// This is the **oracle-only** path: every posterior comes from
    /// [`exact_fusion`], never from the stochastic hardware. To stream
    /// the same workload through prepared plans on the serving stack —
    /// and get [`VideoStats`] measured on the hardware posteriors — use
    /// [`super::pipeline`] (see `MIGRATION.md`).
    pub fn run(&mut self, n_frames: usize) -> VideoStats {
        let mut stats = VideoStats::default();
        for _ in 0..n_frames {
            let det = self.next_detections();
            stats.frames += 1;
            for &(p_rgb, p_th) in &det.confidences {
                // Ref-31 ensembling: misses contribute the prior, so a
                // blind modality cannot veto the other.
                let fused = exact_fusion(fusion_input(p_rgb), fusion_input(p_th));
                stats.record(p_rgb, p_th, fused, self.threshold);
            }
        }
        stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn movie_s1_gains_have_paper_shape() {
        let mut wl = VideoWorkload::new(80);
        let stats = wl.run(1_000);
        assert!(stats.obstacles > 1_000);
        let g_th = stats.gain_vs_thermal();
        let g_rgb = stats.gain_vs_rgb();
        // Paper: +85 % vs thermal, +19 % vs RGB. Shape requirement: fusion
        // dominates both, with the thermal gain much larger.
        assert!(g_th > 0.55 && g_th < 1.2, "thermal gain {g_th}");
        assert!(g_rgb > 0.08 && g_rgb < 0.35, "rgb gain {g_rgb}");
        assert!(g_th > g_rgb * 2.0);
    }

    #[test]
    fn fusion_raises_mean_confidence() {
        let mut wl = VideoWorkload::new(81);
        let stats = wl.run(400);
        let (rgb, th, fused) = stats.mean_confidences();
        assert!(fused > rgb && fused > th, "fused {fused} vs rgb {rgb}, th {th}");
    }

    #[test]
    fn detections_align_with_obstacles() {
        let mut wl = VideoWorkload::new(82);
        for _ in 0..20 {
            let d = wl.next_detections();
            assert_eq!(d.confidences.len(), d.frame.obstacles.len());
            for &(a, b) in &d.confidences {
                assert!((0.0..=1.0).contains(&a) && (0.0..=1.0).contains(&b));
            }
        }
    }

    #[test]
    fn empty_stats_are_safe() {
        let s = VideoStats::default();
        assert_eq!(s.rate(0), 0.0);
        assert_eq!(s.gain_vs_thermal(), 0.0);
        assert_eq!(s.gain_vs_rgb(), 0.0);
    }

    #[test]
    fn zero_baseline_gain_is_infinite_not_zero() {
        // Fused detections over a blind baseline used to report 0.0 —
        // "no gain" in exactly the regimes where fusion gains the most.
        let stats = VideoStats {
            obstacles: 10,
            frames: 3,
            rgb_detections: 0,
            thermal_detections: 3,
            fused_detections: 7,
            ..VideoStats::default()
        };
        assert_eq!(stats.gain_vs_rgb(), f64::INFINITY);
        assert!((stats.gain_vs_thermal() - (7.0 / 3.0 - 1.0)).abs() < 1e-12);
        // Both zero really is "no gain".
        let none = VideoStats { obstacles: 4, frames: 1, ..VideoStats::default() };
        assert_eq!(none.gain_vs_rgb(), 0.0);
        assert_eq!(none.gain_vs_thermal(), 0.0);
    }

    #[test]
    fn night_scene_with_blind_rgb_reports_infinite_gain() {
        // Deterministic night pedestrians (noise-free heads): RGB sees
        // nothing, thermal sees everything, fusion recovers every
        // obstacle — gain vs RGB must be infinite, not 0.
        use crate::scene::{DetectorModel, Modality, Obstacle, ObstacleClass, Visibility};
        let mut rgb = DetectorModel::new(Modality::Rgb);
        let mut th = DetectorModel::new(Modality::Thermal);
        rgb.noise_sigma = 0.0;
        th.noise_sigma = 0.0;
        let mut rng = crate::util::Rng::seeded(90);
        let mut stats = VideoStats { frames: 1, ..VideoStats::default() };
        for distance in [0.2, 0.4, 0.6] {
            let ped = Obstacle {
                class: ObstacleClass::Pedestrian,
                heat: ObstacleClass::Pedestrian.heat(),
                contrast: ObstacleClass::Pedestrian.contrast(),
                distance,
                size: ObstacleClass::Pedestrian.size(),
            };
            let p_rgb = rgb.detect(&ped, Visibility::Night, &mut rng);
            let p_th = th.detect(&ped, Visibility::Night, &mut rng);
            assert!(p_rgb < 0.5, "night RGB must miss (d={distance}): {p_rgb}");
            assert!(p_th > 0.5, "thermal must see the pedestrian (d={distance}): {p_th}");
            let fused = exact_fusion(fusion_input(p_rgb), fusion_input(p_th));
            stats.record(p_rgb, p_th, fused, 0.5);
        }
        assert_eq!(stats.rgb_detections, 0);
        assert_eq!(stats.fused_detections, 3);
        assert_eq!(stats.gain_vs_rgb(), f64::INFINITY);
        assert!(stats.gain_vs_thermal().abs() < 1e-12, "fusion == thermal here");
    }
}
