//! Blocking wire client: one TCP connection, one in-flight request.
//!
//! This is the client the CLI, the load generator, and the integration
//! tests speak — deliberately minimal (synchronous request/response
//! over [`super::wire`]) so its behavior under server errors is easy to
//! reason about. Typed error frames surface two ways:
//!
//! * [`Client::decide_raw`] / [`Client::decide_batch`] hand back the
//!   `(ErrorCode, message)` pair, for callers that branch on the code
//!   (the load generator counting sheds vs deadline misses);
//! * the convenience wrappers ([`Client::decide`], …) fold the pair
//!   into a crate [`Error`]: `Shutdown` frames become
//!   [`Error::Shutdown`], everything else [`Error::Wire`] tagged with
//!   the code name.

use std::net::{TcpStream, ToSocketAddrs};

use crate::{Error, Result};

use super::wire::{
    self, ErrorCode, Frame, WireDecision, WireParams, WirePolicy, WireSpec,
};

/// A typed error frame as seen by the client.
pub type FrameError = (ErrorCode, String);

/// Blocking TCP client bound to one tenant id.
#[derive(Debug)]
pub struct Client {
    stream: TcpStream,
    tenant: String,
}

impl Client {
    /// Connect to a [`super::Server`] and speak as `tenant`.
    pub fn connect<A: ToSocketAddrs>(addr: A, tenant: &str) -> Result<Self> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        Ok(Client { stream, tenant: tenant.to_string() })
    }

    /// The tenant id stamped into every frame header.
    pub fn tenant(&self) -> &str {
        &self.tenant
    }

    /// One request/response exchange.
    fn call(&mut self, frame: &Frame) -> Result<Frame> {
        wire::write_frame(&mut self.stream, &self.tenant, frame)?;
        let (_tenant, reply) = wire::read_frame(&mut self.stream)?;
        Ok(reply)
    }

    /// Compile a plan into this tenant's namespace; every decision on
    /// the returned plan id runs under `policy`.
    pub fn prepare(&mut self, spec: WireSpec, policy: WirePolicy) -> Result<u32> {
        match self.call(&Frame::Prepare { spec, policy })? {
            Frame::Prepared { plan } => Ok(plan),
            Frame::Error { code, message } => Err(error_from_frame(code, message)),
            other => Err(unexpected(&other)),
        }
    }

    /// One decision; typed error frames stay `(code, message)` so the
    /// caller can branch on the code. The outer `Result` is transport
    /// failures only.
    pub fn decide_raw(
        &mut self,
        plan: u32,
        params: WireParams,
    ) -> Result<std::result::Result<WireDecision, FrameError>> {
        match self.call(&Frame::Decide { plan, params })? {
            Frame::Decision(d) => Ok(Ok(d)),
            Frame::Error { code, message } => Ok(Err((code, message))),
            other => Err(unexpected(&other)),
        }
    }

    /// One decision, folded into a crate [`Error`] on failure.
    pub fn decide(&mut self, plan: u32, params: WireParams) -> Result<WireDecision> {
        self.decide_raw(plan, params)?
            .map_err(|(code, message)| error_from_frame(code, message))
    }

    /// A batch against one plan, answered in order; per-entry failures
    /// stay typed.
    #[allow(clippy::type_complexity)]
    pub fn decide_batch(
        &mut self,
        plan: u32,
        params: Vec<WireParams>,
    ) -> Result<Vec<std::result::Result<WireDecision, FrameError>>> {
        match self.call(&Frame::DecideBatch { plan, params })? {
            Frame::DecisionBatch(items) => Ok(items),
            Frame::Error { code, message } => Err(error_from_frame(code, message)),
            other => Err(unexpected(&other)),
        }
    }

    /// This tenant's Prometheus-style exposition.
    pub fn metrics_text(&mut self) -> Result<String> {
        match self.call(&Frame::Metrics)? {
            Frame::MetricsText(text) => Ok(text),
            Frame::Error { code, message } => Err(error_from_frame(code, message)),
            other => Err(unexpected(&other)),
        }
    }

    /// Ask the server to shut down; resolves once it acknowledges.
    pub fn shutdown_server(&mut self) -> Result<()> {
        match self.call(&Frame::Shutdown)? {
            Frame::ShutdownAck => Ok(()),
            Frame::Error { code, message } => Err(error_from_frame(code, message)),
            other => Err(unexpected(&other)),
        }
    }
}

/// Fold a typed error frame into a crate error.
pub fn error_from_frame(code: ErrorCode, message: String) -> Error {
    match code {
        ErrorCode::Shutdown => Error::Shutdown,
        _ => Error::Wire(format!("{}: {message}", code.name())),
    }
}

fn unexpected(frame: &Frame) -> Error {
    Error::Wire(format!("unexpected reply frame type {:#04x}", frame.frame_type()))
}
