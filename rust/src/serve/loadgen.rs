//! Open-loop SLO load harness for the TCP front door.
//!
//! **Open loop**: every request has a scheduled arrival time fixed up
//! front (`start + j·interval`), independent of how fast the server
//! answers. Worker threads sleep until each arrival, fire, and measure
//! latency **from the scheduled arrival** — so a server that falls
//! behind pays the schedule slip in its tail, exactly the coordinated
//! omission a closed-loop harness would hide. Load is an aggregate
//! arrival schedule striped across `connections` blocking clients
//! (connection `i` owns arrivals `i, i+C, i+2C, …`).
//!
//! A run sweeps the same schedule at each overload factor (1×/2×/4× by
//! default), driving a mixed plan set (inference / fusion / network)
//! with per-request random parameters, and reports per-stage
//! p50/p99/p999 completed-decision latency, achieved throughput,
//! shed/deadline-miss counts, and the saturation throughput across
//! stages. [`LoadReport::export_json`] writes the `BENCH_serving.json`
//! artifact CI greps.

use std::io::Write as _;
use std::path::{Path, PathBuf};
use std::thread;
use std::time::{Duration, Instant};

use crate::obs::NsHistogram;
use crate::util::Rng;
use crate::{Error, Result};

use super::client::Client;
use super::wire::{ErrorCode, WireParams, WirePolicy, WireSpec};

/// The embedded network spec the mixed workload queries (a 3-node
/// chain: fog → visibility → alarm, query `fog` given `alarm`).
pub const MIX_NETWORK_TOML: &str = "[network]\nname = \"loadgen\"\n\n[nodes.fog]\nprior = 0.15\n\n\
[nodes.visibility]\nparents = \"fog\"\ncpt = [0.9, 0.3]\n\n\
[nodes.alarm]\nparents = \"visibility\"\ncpt = [0.05, 0.8]\n";

/// Load-generator settings.
#[derive(Debug, Clone)]
pub struct LoadgenConfig {
    /// Server address (`host:port`).
    pub addr: String,
    /// Tenant id every connection speaks as.
    pub tenant: String,
    /// Simulated clients (one blocking connection each).
    pub connections: usize,
    /// Aggregate offered rate at 1×, decisions/s.
    pub rate: f64,
    /// Total requests at 1× (scaled by the overload factor per stage).
    pub requests: u64,
    /// Overload factors to sweep (offered rate = `rate × factor`).
    pub overloads: Vec<f64>,
    /// Per-decision deadline baked into the prepared plans' policy.
    pub deadline_us: Option<u64>,
    /// Stream-length override baked into the prepared plans' policy.
    pub bits: Option<u32>,
    /// Workload mix weights: (inference, fusion, network).
    pub mix: (u32, u32, u32),
    /// Schedule/parameter RNG seed.
    pub seed: u64,
}

impl Default for LoadgenConfig {
    fn default() -> Self {
        Self {
            addr: String::new(),
            tenant: "loadgen".into(),
            connections: 16,
            rate: 5_000.0,
            requests: 2_000,
            overloads: vec![1.0, 2.0, 4.0],
            deadline_us: Some(2_000),
            bits: Some(256),
            mix: (2, 1, 1),
            seed: 42,
        }
    }
}

/// Outcome of one overload stage.
#[derive(Debug, Clone)]
pub struct StageReport {
    /// Overload factor this stage ran at.
    pub overload: f64,
    /// Offered rate, decisions/s.
    pub offered_rps: f64,
    /// Requests fired.
    pub sent: u64,
    /// Decisions answered.
    pub ok: u64,
    /// Typed backpressure / quota rejections (shed admission).
    pub shed: u64,
    /// Typed deadline-miss errors.
    pub deadline_missed: u64,
    /// Anything else (transport failures, internal errors).
    pub other_errors: u64,
    /// Wall-clock stage duration, seconds.
    pub elapsed_s: f64,
    /// Completed decisions per second of wall clock.
    pub achieved_rps: f64,
    /// Completed-decision latency quantiles, measured from the
    /// *scheduled* arrival (µs).
    pub p50_us: f64,
    /// 99th percentile (µs).
    pub p99_us: f64,
    /// 99.9th percentile (µs).
    pub p999_us: f64,
    /// `deadline_missed / sent`.
    pub deadline_miss_rate: f64,
}

impl StageReport {
    /// `"1x"`, `"2x"`, `"4x"`, … (the metric-key suffix).
    pub fn label(&self) -> String {
        overload_label(self.overload)
    }
}

/// Outcome of a full sweep.
#[derive(Debug, Clone)]
pub struct LoadReport {
    /// One entry per overload factor, in sweep order.
    pub stages: Vec<StageReport>,
    /// Highest achieved throughput across stages, decisions/s.
    pub saturation_rps: f64,
}

fn overload_label(o: f64) -> String {
    if o == o.trunc() && o >= 0.0 {
        format!("{}x", o as u64)
    } else {
        format!("{o}x")
    }
}

impl LoadReport {
    /// Flat metric list for export (`BENCH_serving.json` keys). The
    /// unsuffixed SLO headline metrics come from the first stage
    /// (nominal load); every stage also exports suffixed copies.
    pub fn metric_pairs(&self) -> Vec<(String, f64)> {
        let mut pairs = Vec::new();
        if let Some(first) = self.stages.first() {
            pairs.push(("p50_latency_us".into(), first.p50_us));
            pairs.push(("p99_latency_us".into(), first.p99_us));
            pairs.push(("p999_latency_us".into(), first.p999_us));
            pairs.push(("deadline_miss_rate".into(), first.deadline_miss_rate));
        }
        pairs.push(("saturation_throughput_rps".into(), self.saturation_rps));
        for stage in &self.stages {
            let l = stage.label();
            pairs.push((format!("p50_latency_us_{l}"), stage.p50_us));
            pairs.push((format!("p99_latency_us_{l}"), stage.p99_us));
            pairs.push((format!("p999_latency_us_{l}"), stage.p999_us));
            pairs.push((format!("deadline_miss_rate_{l}"), stage.deadline_miss_rate));
            pairs.push((format!("achieved_rps_{l}"), stage.achieved_rps));
            pairs.push((format!("offered_rps_{l}"), stage.offered_rps));
        }
        pairs
    }

    /// Render the sweep as an aligned text table.
    pub fn to_table(&self) -> String {
        let mut out = String::new();
        out.push_str(
            "stage     offered/s   achieved/s     sent       ok     shed   missed   errors \
             p50_us    p99_us   p999_us  miss_rate\n",
        );
        for s in &self.stages {
            out.push_str(&format!(
                "{:<8} {:>10.0} {:>12.0} {:>8} {:>8} {:>8} {:>8} {:>8} {:>8.1} {:>9.1} {:>9.1} \
                 {:>10.4}\n",
                s.label(),
                s.offered_rps,
                s.achieved_rps,
                s.sent,
                s.ok,
                s.shed,
                s.deadline_missed,
                s.other_errors,
                s.p50_us,
                s.p99_us,
                s.p999_us,
                s.deadline_miss_rate,
            ));
        }
        out.push_str(&format!("saturation throughput: {:.0} decisions/s\n", self.saturation_rps));
        out
    }

    /// Write the `BENCH_serving.json` artifact: a `metrics` map (flat
    /// SLO numbers, 4-decimal) plus the per-stage breakdown.
    pub fn export_json(&self, path: &Path) -> std::io::Result<()> {
        let mut out = String::from("{\n  \"group\": \"serving\",\n  \"metrics\": {\n");
        let pairs = self.metric_pairs();
        for (i, (name, value)) in pairs.iter().enumerate() {
            let comma = if i + 1 < pairs.len() { "," } else { "" };
            out.push_str(&format!("    \"{name}\": {value:.4}{comma}\n"));
        }
        out.push_str("  },\n  \"stages\": [\n");
        for (i, s) in self.stages.iter().enumerate() {
            let comma = if i + 1 < self.stages.len() { "," } else { "" };
            out.push_str(&format!(
                "    {{\"overload\": \"{}\", \"offered_rps\": {:.1}, \"achieved_rps\": {:.1}, \
                 \"sent\": {}, \"ok\": {}, \"shed\": {}, \"deadline_missed\": {}, \
                 \"other_errors\": {}, \"elapsed_s\": {:.3}, \"p50_us\": {:.1}, \
                 \"p99_us\": {:.1}, \"p999_us\": {:.1}, \"deadline_miss_rate\": {:.4}}}{comma}\n",
                s.label(),
                s.offered_rps,
                s.achieved_rps,
                s.sent,
                s.ok,
                s.shed,
                s.deadline_missed,
                s.other_errors,
                s.elapsed_s,
                s.p50_us,
                s.p99_us,
                s.p999_us,
                s.deadline_miss_rate,
            ));
        }
        out.push_str("  ]\n}\n");
        let mut f = std::fs::File::create(path)?;
        f.write_all(out.as_bytes())
    }
}

/// Where `bayes-mem loadgen` writes its artifact by default: next to
/// the other `BENCH_*.json` exports at the repository root.
pub fn default_export_path() -> PathBuf {
    let manifest = Path::new(env!("CARGO_MANIFEST_DIR"));
    manifest.parent().unwrap_or(manifest).join("BENCH_serving.json")
}

/// The three mixed-workload plan ids, prepared once per run.
#[derive(Clone, Copy)]
struct MixPlans {
    inference: u32,
    fusion: u32,
    network: u32,
}

fn prepare_mix(client: &mut Client, cfg: &LoadgenConfig) -> Result<MixPlans> {
    let policy = WirePolicy {
        deadline_us: cfg.deadline_us,
        bits: cfg.bits,
        threshold: None,
        max_half_width: None,
        allow_partial: false,
    };
    Ok(MixPlans {
        inference: client.prepare(WireSpec::Inference, policy)?,
        fusion: client.prepare(WireSpec::Fusion { modalities: 2 }, policy)?,
        network: client.prepare(
            WireSpec::Network {
                spec_toml: MIX_NETWORK_TOML.into(),
                query: "fog".into(),
                evidence: vec![("alarm".into(), true)],
            },
            policy,
        )?,
    })
}

/// Per-thread stage tallies, merged after join.
#[derive(Default)]
struct Tally {
    sent: u64,
    ok: u64,
    shed: u64,
    deadline_missed: u64,
    other_errors: u64,
    hist: NsHistogram,
}

impl Tally {
    fn merge(&mut self, other: &Tally) {
        self.sent += other.sent;
        self.ok += other.ok;
        self.shed += other.shed;
        self.deadline_missed += other.deadline_missed;
        self.other_errors += other.other_errors;
        self.hist.merge(&other.hist);
    }
}

fn pick_request(rng: &mut Rng, mix: (u32, u32, u32), plans: &MixPlans) -> (u32, WireParams) {
    let total = (mix.0 + mix.1 + mix.2).max(1);
    let r = (rng.next_u64() % total as u64) as u32;
    if r < mix.0 {
        (
            plans.inference,
            WireParams::Inference {
                prior: rng.range_f64(0.2, 0.8),
                likelihood: rng.range_f64(0.55, 0.95),
                likelihood_not: rng.range_f64(0.05, 0.45),
            },
        )
    } else if r < mix.0 + mix.1 {
        (
            plans.fusion,
            WireParams::Fusion {
                posteriors: vec![rng.range_f64(0.3, 0.9), rng.range_f64(0.3, 0.9)],
            },
        )
    } else {
        (plans.network, WireParams::Network { overrides: vec![] })
    }
}

fn run_stage(cfg: &LoadgenConfig, overload: f64, plans: &MixPlans) -> Result<StageReport> {
    let offered_rps = cfg.rate * overload;
    let total = ((cfg.requests as f64) * overload).round() as u64;
    let interval = Duration::from_secs_f64(1.0 / offered_rps.max(1.0));
    let conns = cfg.connections.clamp(1, total.max(1) as usize);
    let start = Instant::now() + Duration::from_millis(5);

    let mut threads = Vec::with_capacity(conns);
    for i in 0..conns {
        let cfg = cfg.clone();
        let mix = *plans;
        let handle = thread::Builder::new().name(format!("loadgen-{i}")).spawn(
            move || -> Result<Tally> {
                let mut client = Client::connect(&cfg.addr, &cfg.tenant)?;
                let mut rng =
                    Rng::seeded(cfg.seed ^ (overload.to_bits()) ^ ((i as u64) << 17));
                let mut tally = Tally::default();
                let mut j = i as u64;
                while j < total {
                    let target = start + interval.mul_f64(j as f64);
                    let now = Instant::now();
                    if target > now {
                        thread::sleep(target - now);
                    }
                    let (plan, params) = pick_request(&mut rng, cfg.mix, &mix);
                    tally.sent += 1;
                    match client.decide_raw(plan, params) {
                        Ok(Ok(_decision)) => {
                            tally.ok += 1;
                            let ns = target.elapsed().as_nanos().min(u64::MAX as u128) as u64;
                            tally.hist.record(ns);
                        }
                        Ok(Err((ErrorCode::Deadline, _))) => tally.deadline_missed += 1,
                        Ok(Err((
                            ErrorCode::Backpressure | ErrorCode::QuotaExhausted,
                            _,
                        ))) => tally.shed += 1,
                        Ok(Err(_)) => tally.other_errors += 1,
                        Err(_) => {
                            // Transport failure: the connection is gone;
                            // count the rest of this stripe as errors.
                            tally.other_errors += 1 + (total.saturating_sub(j) / conns as u64);
                            break;
                        }
                    }
                    j += conns as u64;
                }
                Ok(tally)
            },
        );
        threads.push(handle?);
    }

    let mut tally = Tally::default();
    for t in threads {
        let part = t
            .join()
            .map_err(|_| Error::Runtime("loadgen worker panicked".into()))??;
        tally.merge(&part);
    }
    let elapsed_s = start.elapsed().as_secs_f64().max(1e-9);
    Ok(StageReport {
        overload,
        offered_rps,
        sent: tally.sent,
        ok: tally.ok,
        shed: tally.shed,
        deadline_missed: tally.deadline_missed,
        other_errors: tally.other_errors,
        elapsed_s,
        achieved_rps: tally.ok as f64 / elapsed_s,
        p50_us: tally.hist.quantile_ns(0.5) as f64 / 1_000.0,
        p99_us: tally.hist.quantile_ns(0.99) as f64 / 1_000.0,
        p999_us: tally.hist.quantile_ns(0.999) as f64 / 1_000.0,
        deadline_miss_rate: if tally.sent == 0 {
            0.0
        } else {
            tally.deadline_missed as f64 / tally.sent as f64
        },
    })
}

/// Run the sweep: prepare the mixed plan set once, then drive the
/// open-loop schedule at every overload factor in turn.
pub fn run(cfg: &LoadgenConfig) -> Result<LoadReport> {
    if cfg.rate <= 0.0 || !cfg.rate.is_finite() {
        return Err(Error::Config(format!("loadgen rate must be > 0, got {}", cfg.rate)));
    }
    if cfg.requests == 0 {
        return Err(Error::Config("loadgen requests must be > 0".into()));
    }
    let overloads = if cfg.overloads.is_empty() { vec![1.0] } else { cfg.overloads.clone() };
    if let Some(bad) = overloads.iter().find(|o| !o.is_finite() || **o <= 0.0) {
        return Err(Error::Config(format!("overload factors must be > 0, got {bad}")));
    }
    let mut control = Client::connect(&cfg.addr, &cfg.tenant)?;
    let plans = prepare_mix(&mut control, cfg)?;
    let mut stages = Vec::with_capacity(overloads.len());
    for overload in overloads {
        stages.push(run_stage(cfg, overload, &plans)?);
    }
    let saturation_rps = stages.iter().map(|s| s.achieved_rps).fold(0.0, f64::max);
    Ok(LoadReport { stages, saturation_rps })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn overload_labels_are_stable() {
        assert_eq!(overload_label(1.0), "1x");
        assert_eq!(overload_label(4.0), "4x");
        assert_eq!(overload_label(0.5), "0.5x");
    }

    #[test]
    fn metric_pairs_carry_slo_keys_per_stage() {
        let stage = |o: f64| StageReport {
            overload: o,
            offered_rps: 1000.0 * o,
            sent: 100,
            ok: 90,
            shed: 8,
            deadline_missed: 2,
            other_errors: 0,
            elapsed_s: 0.1,
            achieved_rps: 900.0,
            p50_us: 100.0,
            p99_us: 400.0,
            p999_us: 800.0,
            deadline_miss_rate: 0.02,
        };
        let report =
            LoadReport { stages: vec![stage(1.0), stage(2.0), stage(4.0)], saturation_rps: 900.0 };
        let pairs = report.metric_pairs();
        let has = |k: &str| pairs.iter().any(|(n, _)| n == k);
        for key in [
            "p50_latency_us",
            "p99_latency_us",
            "p999_latency_us",
            "deadline_miss_rate",
            "saturation_throughput_rps",
            "p99_latency_us_1x",
            "p99_latency_us_2x",
            "p99_latency_us_4x",
            "deadline_miss_rate_4x",
            "achieved_rps_2x",
        ] {
            assert!(has(key), "missing metric {key}");
        }
    }

    #[test]
    fn export_json_is_balanced_and_greppable() {
        let report = LoadReport {
            stages: vec![StageReport {
                overload: 1.0,
                offered_rps: 2500.0,
                sent: 10,
                ok: 10,
                shed: 0,
                deadline_missed: 0,
                other_errors: 0,
                elapsed_s: 0.004,
                achieved_rps: 2500.0,
                p50_us: 120.0,
                p99_us: 300.0,
                p999_us: 350.0,
                deadline_miss_rate: 0.0,
            }],
            saturation_rps: 2500.0,
        };
        let dir = std::env::temp_dir().join("bayes_mem_loadgen_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_serving.json");
        report.export_json(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.matches('{').count(), text.matches('}').count());
        assert!(text.contains("\"p99_latency_us\""), "{text}");
        assert!(text.contains("\"deadline_miss_rate\""), "{text}");
        assert!(text.contains("\"saturation_throughput_rps\""), "{text}");
        let table = report.to_table();
        assert!(table.contains("1x"), "{table}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn bad_config_is_rejected() {
        let cfg = LoadgenConfig { rate: 0.0, ..LoadgenConfig::default() };
        assert!(run(&cfg).is_err());
        let cfg = LoadgenConfig { requests: 0, ..LoadgenConfig::default() };
        assert!(run(&cfg).is_err());
        let cfg = LoadgenConfig {
            overloads: vec![-1.0],
            addr: "127.0.0.1:1".into(),
            ..LoadgenConfig::default()
        };
        assert!(run(&cfg).is_err());
    }
}
