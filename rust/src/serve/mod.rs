//! Production front door: TCP wire protocol, multi-tenant sharded
//! serving, and an open-loop SLO load harness.
//!
//! Everything in-process stays on [`crate::coordinator`] handles; this
//! module is the network boundary in front of them:
//!
//! * [`wire`] — the length-prefixed binary protocol (magic + version +
//!   frame type + tenant id), with strict bounds-checked decoding:
//!   malformed, truncated, or oversized frames become typed error
//!   frames, never panics or unbounded allocations.
//! * [`server`] — `bayes-mem serve`: a [`Server`] accepts concurrent
//!   connections, pins each tenant to one of N coordinator shards, and
//!   gives every tenant its own plan namespace, plan cache, quotas,
//!   admission policy (block vs shed), and metrics registry — one
//!   tenant exhausting its quota cannot evict another tenant's plans
//!   or starve its queue.
//! * [`client`] — the blocking [`Client`] the CLI, tests, and load
//!   generator speak.
//! * [`loadgen`] — `bayes-mem loadgen`: an open-loop arrival schedule
//!   (latency measured from *scheduled* arrival, so schedule slip is
//!   charged to the server) swept at 1×/2×/4× overload, exporting
//!   p50/p99/p999, deadline-miss rate, and saturation throughput to
//!   `BENCH_serving.json`.
//!
//! Control plane (prepare, metrics, shutdown) and data plane (decide,
//! decide-batch) share one connection; requests on a connection are
//! answered in order.

pub mod client;
pub mod loadgen;
pub mod server;
pub mod wire;

pub use client::{error_from_frame, Client, FrameError};
pub use loadgen::{LoadReport, LoadgenConfig, StageReport};
pub use server::{Server, TenantSpec};
pub use wire::{
    ErrorCode, Frame, WireDecision, WireError, WireParams, WirePolicy, WireSpec,
};
