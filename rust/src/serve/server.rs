//! The TCP front door: a `std::net` listener fanning concurrent client
//! connections onto **sharded coordinators** with per-tenant isolation.
//!
//! Every request frame names a tenant. A tenant owns:
//!
//! * a **plan namespace** — its own [`PlanCache`] view plus a
//!   wire-plan-id registry, so one tenant churning plans cannot evict
//!   another tenant's compiled netlists;
//! * **quotas** — a plan-count cap and an in-flight decision cap,
//!   enforced at the front door before the shard's admission queue is
//!   touched;
//! * an **admission policy** — shed-on-overflow (typed backpressure
//!   error, flat tail latency) or blocking admission (absorb the
//!   backlog, PR 5 semantics), chosen per tenant;
//! * a **metrics registry** — an isolated [`Metrics`] instance behind
//!   the wire `Metrics` frame and `bayes-mem metrics --tenant`.
//!
//! Tenants are pinned to one of `serve.shards` coordinators by a
//! stable hash of the tenant id, so a tenant's decisions always meet
//! the same admission queue (its backpressure story is coherent) while
//! aggregate load spreads across shards. The control plane (Prepare)
//! compiles on the connection thread; the data plane (Decide /
//! DecideBatch) only binds parameters and rides the shard's batcher.

use std::collections::hash_map::DefaultHasher;
use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::{self, JoinHandle};
use std::time::Duration;

use crate::config::{AdmissionPolicy, AppConfig};
use crate::coordinator::{
    Coordinator, CoordinatorHandle, Metrics, MetricsSnapshot, PlanCache, PlanSpec, Policy,
    PreparedPlan,
};
use crate::network::BayesNet;
use crate::obs::expose;
use crate::{Error, Result};

use super::wire::{self, ErrorCode, Frame, WireDecision, WireParams, WireSpec};

/// Per-tenant serving contract: admission behavior plus quotas.
#[derive(Debug, Clone)]
pub struct TenantSpec {
    /// Tenant id as it appears in frame headers.
    pub name: String,
    /// Queue-full behavior for this tenant's decisions.
    pub admission: AdmissionPolicy,
    /// In-flight decision quota.
    pub max_inflight: usize,
    /// Plan-namespace quota (registered wire plans).
    pub max_plans: usize,
    /// Capacity of the tenant's private plan-cache view.
    pub plan_cache_capacity: usize,
}

impl TenantSpec {
    /// The default tenant contract from the `[serve]` config section.
    pub fn from_config(name: &str, cfg: &AppConfig) -> Self {
        TenantSpec {
            name: name.to_string(),
            admission: cfg.serve.admission,
            max_inflight: cfg.serve.max_inflight,
            max_plans: cfg.serve.max_plans,
            plan_cache_capacity: cfg.serve.plan_cache_capacity,
        }
    }
}

/// A registered wire plan: the compiled netlist plus the policy every
/// decision on it runs under.
struct PlanEntry {
    plan: Arc<PreparedPlan>,
    policy: Policy,
}

/// One tenant's isolated serving state.
struct Tenant {
    spec: TenantSpec,
    /// Which coordinator shard this tenant's decisions ride.
    shard: usize,
    /// Isolated metrics registry (per-tenant exposition).
    metrics: Arc<Metrics>,
    /// Private plan-cache view: this tenant's churn evicts only here.
    cache: PlanCache,
    /// Wire plan id → compiled plan + policy.
    plans: Mutex<HashMap<u32, PlanEntry>>,
    next_plan: AtomicU32,
    inflight: AtomicU64,
}

impl Tenant {
    fn new(spec: TenantSpec, shard: usize) -> Self {
        let metrics = Arc::new(Metrics::new());
        let cache = PlanCache::with_metrics(spec.plan_cache_capacity, Arc::clone(&metrics));
        Tenant {
            spec,
            shard,
            metrics,
            cache,
            plans: Mutex::new(HashMap::new()),
            next_plan: AtomicU32::new(1),
            inflight: AtomicU64::new(0),
        }
    }

    /// Reserve `n` in-flight slots against the quota, or fail without
    /// disturbing other tenants.
    fn acquire_inflight(
        &self,
        n: u64,
    ) -> std::result::Result<InflightGuard<'_>, (ErrorCode, String)> {
        let prev = self.inflight.fetch_add(n, Ordering::AcqRel);
        if prev + n > self.spec.max_inflight as u64 {
            self.inflight.fetch_sub(n, Ordering::AcqRel);
            return Err((
                ErrorCode::QuotaExhausted,
                format!(
                    "tenant {:?} in-flight quota exhausted ({} + {n} > {})",
                    self.spec.name, prev, self.spec.max_inflight
                ),
            ));
        }
        Ok(InflightGuard { tenant: self, n })
    }
}

/// RAII release of reserved in-flight slots.
struct InflightGuard<'a> {
    tenant: &'a Tenant,
    n: u64,
}

impl Drop for InflightGuard<'_> {
    fn drop(&mut self) {
        self.tenant.inflight.fetch_sub(self.n, Ordering::AcqRel);
    }
}

/// Shared server state reachable from every connection thread.
struct Inner {
    app: AppConfig,
    handles: Vec<CoordinatorHandle>,
    tenants: Mutex<HashMap<String, Arc<Tenant>>>,
    /// Pre-registered tenant contracts (overrides of the config
    /// template), applied when the tenant first appears on the wire.
    overrides: HashMap<String, TenantSpec>,
    stop: AtomicBool,
}

/// The TCP serving front door. Binds at [`Server::start`], serves until
/// a wire `Shutdown` frame (or [`Server::shutdown`]), and joins its
/// coordinator shards on the way down.
pub struct Server {
    inner: Arc<Inner>,
    addr: SocketAddr,
    accept: Option<JoinHandle<()>>,
    shards: Vec<Coordinator>,
}

impl Server {
    /// Bind `listen` (e.g. `"127.0.0.1:0"`) and start `app.serve.shards`
    /// coordinator shards behind it. `tenants` pre-registers per-tenant
    /// contracts; tenants not listed get the `[serve]` template on
    /// first use.
    pub fn start(listen: &str, app: &AppConfig, tenants: Vec<TenantSpec>) -> Result<Self> {
        app.validate()?;
        let listener = TcpListener::bind(listen)?;
        let addr = listener.local_addr()?;
        let mut shards = Vec::with_capacity(app.serve.shards);
        let mut handles = Vec::with_capacity(app.serve.shards);
        for _ in 0..app.serve.shards {
            let shard = Coordinator::start(app)?;
            handles.push(shard.handle());
            shards.push(shard);
        }
        let overrides = tenants.into_iter().map(|t| (t.name.clone(), t)).collect();
        let inner = Arc::new(Inner {
            app: app.clone(),
            handles,
            tenants: Mutex::new(HashMap::new()),
            overrides,
            stop: AtomicBool::new(false),
        });
        let accept = {
            let inner = Arc::clone(&inner);
            thread::Builder::new()
                .name("serve-accept".into())
                .spawn(move || accept_loop(listener, inner))
                .map_err(Error::Io)?
        };
        Ok(Server { inner, addr, accept: Some(accept), shards })
    }

    /// The bound address (use with `"127.0.0.1:0"` to discover the
    /// ephemeral port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// `true` once a shutdown has been requested (wire frame or local).
    pub fn shutdown_requested(&self) -> bool {
        self.inner.stop.load(Ordering::Acquire)
    }

    /// Names of tenants that have appeared on the wire so far.
    pub fn tenant_names(&self) -> Vec<String> {
        let mut names: Vec<String> =
            self.inner.tenants.lock().unwrap().keys().cloned().collect();
        names.sort();
        names
    }

    /// One tenant's isolated metrics snapshot.
    pub fn tenant_snapshot(&self, name: &str) -> Option<MetricsSnapshot> {
        let tenants = self.inner.tenants.lock().unwrap();
        tenants.get(name).map(|t| t.metrics.snapshot())
    }

    /// One tenant's Prometheus-style exposition
    /// ([`expose::prometheus_tenant`]).
    pub fn tenant_exposition(&self, name: &str) -> Option<String> {
        self.tenant_snapshot(name).map(|snap| expose::prometheus_tenant(name, &snap))
    }

    /// Aggregate exposition of one coordinator shard (shard-level
    /// counters cut across tenants).
    pub fn shard_exposition(&self, shard: usize) -> Option<String> {
        self.inner.handles.get(shard).map(|h| h.exposition())
    }

    /// Which coordinator shard `name`'s decisions would ride (stable
    /// across restarts — useful for capacity planning and for tests
    /// that need tenants on distinct shards).
    pub fn shard_of(&self, name: &str) -> usize {
        shard_for(name, self.inner.handles.len())
    }

    /// Block until a shutdown is requested (a wire `Shutdown` frame),
    /// then tear down listener and shards.
    pub fn run(self) -> Result<()> {
        while !self.inner.stop.load(Ordering::Acquire) {
            thread::sleep(Duration::from_millis(25));
        }
        self.shutdown()
    }

    /// Stop accepting, join the accept thread, and shut the coordinator
    /// shards down (draining their queues).
    pub fn shutdown(mut self) -> Result<()> {
        self.inner.stop.store(true, Ordering::Release);
        // Unblock the accept loop with a throwaway connection.
        let _ = TcpStream::connect(self.addr);
        if let Some(accept) = self.accept.take() {
            let _ = accept.join();
        }
        for shard in self.shards.drain(..) {
            shard.shutdown();
        }
        Ok(())
    }
}

/// Stable tenant → shard pinning.
fn shard_for(name: &str, shards: usize) -> usize {
    let mut h = DefaultHasher::new();
    name.hash(&mut h);
    (h.finish() % shards.max(1) as u64) as usize
}

fn accept_loop(listener: TcpListener, inner: Arc<Inner>) {
    for stream in listener.incoming() {
        if inner.stop.load(Ordering::Acquire) {
            break;
        }
        let Ok(stream) = stream else { continue };
        let inner = Arc::clone(&inner);
        // Connection threads are detached: they exit when the client
        // closes (or on an unrecoverable wire error), and a server
        // shutdown fails their submissions with typed errors.
        let _ = thread::Builder::new()
            .name("serve-conn".into())
            .spawn(move || handle_conn(stream, inner));
    }
}

fn handle_conn(mut stream: TcpStream, inner: Arc<Inner>) {
    let _ = stream.set_nodelay(true);
    loop {
        match wire::read_frame(&mut stream) {
            Ok((tenant, frame)) => {
                let (reply, close) = inner.serve_frame(&tenant, frame);
                if wire::write_frame(&mut stream, &tenant, &reply).is_err() || close {
                    break;
                }
            }
            Err(wire::WireError::Closed) => break,
            Err(e) => {
                // Typed error frame back to the peer; carry on only if
                // the stream is still frame-aligned.
                let reply = Frame::Error { code: e.code(), message: e.to_string() };
                let aligned = e.recoverable();
                if wire::write_frame(&mut stream, "", &reply).is_err() || !aligned {
                    break;
                }
            }
        }
    }
}

impl Inner {
    /// Fetch or lazily create the tenant for `name`.
    fn tenant(&self, name: &str) -> std::result::Result<Arc<Tenant>, (ErrorCode, String)> {
        if name.is_empty() {
            return Err((ErrorCode::UnknownTenant, "empty tenant id".into()));
        }
        let mut tenants = self.tenants.lock().unwrap();
        if let Some(t) = tenants.get(name) {
            return Ok(Arc::clone(t));
        }
        let spec = self
            .overrides
            .get(name)
            .cloned()
            .unwrap_or_else(|| TenantSpec::from_config(name, &self.app));
        let tenant = Arc::new(Tenant::new(spec, shard_for(name, self.handles.len())));
        tenants.insert(name.to_string(), Arc::clone(&tenant));
        Ok(tenant)
    }

    /// Serve one request frame; returns the reply and whether the
    /// connection should close afterwards.
    fn serve_frame(&self, tenant_name: &str, frame: Frame) -> (Frame, bool) {
        if self.stop.load(Ordering::Acquire) && !matches!(frame, Frame::Shutdown) {
            return (err_frame((ErrorCode::Shutdown, "server is shutting down".into())), true);
        }
        match frame {
            Frame::Shutdown => {
                self.stop.store(true, Ordering::Release);
                (Frame::ShutdownAck, true)
            }
            Frame::Metrics => match self.tenant(tenant_name) {
                Ok(t) => {
                    let text = expose::prometheus_tenant(&t.spec.name, &t.metrics.snapshot());
                    (Frame::MetricsText(text), false)
                }
                Err(e) => (err_frame(e), false),
            },
            Frame::Prepare { spec, policy } => match self.prepare(tenant_name, spec, policy) {
                Ok(plan) => (Frame::Prepared { plan }, false),
                Err(e) => (err_frame(e), false),
            },
            Frame::Decide { plan, params } => match self.decide(tenant_name, plan, &params) {
                Ok(d) => (Frame::Decision(d), false),
                Err(e) => (err_frame(e), false),
            },
            Frame::DecideBatch { plan, params } => {
                match self.decide_batch(tenant_name, plan, &params) {
                    Ok(items) => (Frame::DecisionBatch(items), false),
                    Err(e) => (err_frame(e), false),
                }
            }
            // A response frame arriving as a request is a peer bug; the
            // stream is aligned, so answer typed and keep serving.
            other => (
                err_frame((
                    ErrorCode::Malformed,
                    format!("frame type {:#04x} is not a request", other.frame_type()),
                )),
                false,
            ),
        }
    }

    /// Control plane: compile `spec` into the tenant's namespace.
    fn prepare(
        &self,
        tenant_name: &str,
        spec: WireSpec,
        policy: wire::WirePolicy,
    ) -> std::result::Result<u32, (ErrorCode, String)> {
        let tenant = self.tenant(tenant_name)?;
        let policy = policy.to_policy();
        policy.validate().map_err(|e| (ErrorCode::Rejected, e.to_string()))?;
        {
            let plans = tenant.plans.lock().unwrap();
            if plans.len() >= tenant.spec.max_plans {
                return Err((
                    ErrorCode::QuotaExhausted,
                    format!(
                        "tenant {:?} plan quota exhausted ({} plans)",
                        tenant.spec.name, tenant.spec.max_plans
                    ),
                ));
            }
        }
        let spec = lower_spec(spec).map_err(|e| (ErrorCode::Rejected, e.to_string()))?;
        let plan = tenant
            .cache
            .prepare(spec)
            .map_err(|e| (ErrorCode::Rejected, e.to_string()))?;
        let id = tenant.next_plan.fetch_add(1, Ordering::AcqRel);
        tenant.plans.lock().unwrap().insert(id, PlanEntry { plan, policy });
        Ok(id)
    }

    /// Data plane: one decision against a registered plan.
    fn decide(
        &self,
        tenant_name: &str,
        plan: u32,
        params: &WireParams,
    ) -> std::result::Result<WireDecision, (ErrorCode, String)> {
        let tenant = self.tenant(tenant_name)?;
        let _slot = tenant.acquire_inflight(1).inspect_err(|_| tenant.metrics.on_reject())?;
        let (prepared, policy) = lookup_plan(&tenant, plan)?;
        self.decide_on_shard(&tenant, &prepared, policy, params)
    }

    /// Data plane: a batch against one plan, answered in order. The
    /// whole batch reserves in-flight quota up front; per-decision
    /// failures are reported per entry without failing the frame.
    #[allow(clippy::type_complexity)]
    fn decide_batch(
        &self,
        tenant_name: &str,
        plan: u32,
        params: &[WireParams],
    ) -> std::result::Result<
        Vec<std::result::Result<WireDecision, (ErrorCode, String)>>,
        (ErrorCode, String),
    > {
        let tenant = self.tenant(tenant_name)?;
        let _slots = tenant
            .acquire_inflight(params.len() as u64)
            .inspect_err(|_| tenant.metrics.on_reject())?;
        let (prepared, policy) = lookup_plan(&tenant, plan)?;
        let handle = &self.handles[tenant.shard];
        // Submit everything up front so the shard's dynamic batcher can
        // form full batches, then collect in order.
        let pendings: Vec<_> = params
            .iter()
            .map(|p| self.submit_one(&tenant, handle, &prepared, policy, p))
            .collect();
        Ok(pendings
            .into_iter()
            .map(|pending| pending.and_then(|p| self.wait_one(&tenant, &prepared, p)))
            .collect())
    }

    fn decide_on_shard(
        &self,
        tenant: &Tenant,
        prepared: &Arc<PreparedPlan>,
        policy: Policy,
        params: &WireParams,
    ) -> std::result::Result<WireDecision, (ErrorCode, String)> {
        let handle = &self.handles[tenant.shard];
        let pending = self.submit_one(tenant, handle, prepared, policy, params)?;
        self.wait_one(tenant, prepared, pending)
    }

    fn submit_one(
        &self,
        tenant: &Tenant,
        handle: &CoordinatorHandle,
        prepared: &Arc<PreparedPlan>,
        policy: Policy,
        params: &WireParams,
    ) -> std::result::Result<crate::coordinator::PendingDecision, (ErrorCode, String)> {
        let params = params.to_params();
        let submitted = match tenant.spec.admission {
            AdmissionPolicy::Block => handle.submit_prepared_blocking(prepared, params, policy),
            AdmissionPolicy::Shed => handle.submit_prepared(prepared, params, policy),
        };
        match submitted {
            Ok(pending) => {
                tenant.metrics.on_submit();
                Ok(pending)
            }
            Err(e) => {
                tenant.metrics.on_reject();
                Err(classify(&e))
            }
        }
    }

    fn wait_one(
        &self,
        tenant: &Tenant,
        prepared: &Arc<PreparedPlan>,
        pending: crate::coordinator::PendingDecision,
    ) -> std::result::Result<WireDecision, (ErrorCode, String)> {
        match pending.wait() {
            Ok(d) => {
                tenant.metrics.on_complete(d.latency, d.hardware_ns, prepared.tag());
                Ok(WireDecision::from_decision(&d))
            }
            Err(e @ Error::Deadline(_)) => {
                tenant.metrics.on_deadline_miss();
                Err(classify(&e))
            }
            Err(e) => {
                tenant.metrics.on_fail();
                Err(classify(&e))
            }
        }
    }
}

fn lookup_plan(
    tenant: &Tenant,
    plan: u32,
) -> std::result::Result<(Arc<PreparedPlan>, Policy), (ErrorCode, String)> {
    let plans = tenant.plans.lock().unwrap();
    match plans.get(&plan) {
        Some(entry) => Ok((Arc::clone(&entry.plan), entry.policy)),
        None => Err((
            ErrorCode::UnknownPlan,
            format!("tenant {:?} has no plan {plan}", tenant.spec.name),
        )),
    }
}

/// Lower a wire spec into the coordinator's [`PlanSpec`] (network specs
/// compile through the same TOML parser as the CLI's `--spec` files).
fn lower_spec(spec: WireSpec) -> Result<PlanSpec> {
    Ok(match spec {
        WireSpec::Inference => PlanSpec::Inference,
        WireSpec::Fusion { modalities } => PlanSpec::Fusion { modalities: modalities as usize },
        WireSpec::Network { spec_toml, query, evidence } => {
            let net = BayesNet::from_toml_str(&spec_toml)?;
            PlanSpec::Network { net: Arc::new(net), query, evidence }
        }
    })
}

fn err_frame((code, message): (ErrorCode, String)) -> Frame {
    Frame::Error { code, message }
}

/// Map crate errors onto wire error codes.
fn classify(e: &Error) -> (ErrorCode, String) {
    let code = match e {
        Error::Shutdown => ErrorCode::Shutdown,
        Error::Deadline(_) => ErrorCode::Deadline,
        Error::Coordinator(msg) if msg.contains("backpressure") => ErrorCode::Backpressure,
        Error::ProbabilityRange { .. }
        | Error::LengthMismatch { .. }
        | Error::Config(_)
        | Error::Network(_)
        | Error::Toml(_) => ErrorCode::Rejected,
        _ => ErrorCode::Internal,
    };
    (code, e.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_pinning_is_stable_and_in_range() {
        for shards in 1..6 {
            for name in ["alpha", "beta", "cam-ingest", "x"] {
                let s = shard_for(name, shards);
                assert!(s < shards);
                assert_eq!(s, shard_for(name, shards), "pinning must be deterministic");
            }
        }
    }

    #[test]
    fn classify_maps_typed_errors() {
        assert_eq!(classify(&Error::Shutdown).0, ErrorCode::Shutdown);
        assert_eq!(classify(&Error::Deadline(Duration::from_micros(1))).0, ErrorCode::Deadline);
        assert_eq!(
            classify(&Error::Coordinator("admission queue full (backpressure)".into())).0,
            ErrorCode::Backpressure
        );
        assert_eq!(classify(&Error::Network("bad dag".into())).0, ErrorCode::Rejected);
        assert_eq!(classify(&Error::Runtime("boom".into())).0, ErrorCode::Internal);
    }

    #[test]
    fn lower_spec_compiles_network_toml() {
        let toml = "[network]\nname = \"mini\"\n\n[nodes.a]\nprior = 0.3\n";
        let spec = lower_spec(WireSpec::Network {
            spec_toml: toml.into(),
            query: "a".into(),
            evidence: vec![],
        });
        match spec {
            Ok(PlanSpec::Network { net, query, .. }) => {
                assert_eq!(net.len(), 1);
                assert_eq!(query, "a");
            }
            other => panic!("unexpected: {other:?}"),
        }
        assert!(lower_spec(WireSpec::Network {
            spec_toml: "not toml [".into(),
            query: "a".into(),
            evidence: vec![],
        })
        .is_err());
    }
}
