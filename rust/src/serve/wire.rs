//! Length-prefixed binary wire protocol for the TCP serving front door.
//!
//! Every frame is `header ‖ tenant ‖ payload`:
//!
//! | offset | size | field |
//! |---|---|---|
//! | 0 | 4 | magic `b"BMWP"` |
//! | 4 | 1 | protocol version ([`VERSION`]) |
//! | 5 | 1 | frame type code |
//! | 6 | 1 | tenant-id length (bytes, ≤ [`MAX_TENANT_LEN`]) |
//! | 7 | 1 | reserved (must be 0) |
//! | 8 | 4 | payload length, u32 LE (≤ [`MAX_PAYLOAD`]) |
//! | 12 | n | tenant id (UTF-8) |
//! | 12+n | m | payload |
//!
//! Integers are little-endian; floats are IEEE-754 bit patterns;
//! strings are `u32 length ‖ UTF-8 bytes`. Decoding is strict and
//! bounds-checked end to end: oversized frames are rejected **before**
//! any allocation, truncated or garbage input yields a typed
//! [`WireError`] (never a panic), and payloads with trailing bytes are
//! malformed. Errors split into two recovery classes (see
//! [`WireError::recoverable`]): a stream that is still frame-aligned
//! (the bad bytes were fully consumed) can carry on after an error
//! frame; a desynchronized stream must be closed.

use std::io::{self, Read, Write};
use std::time::Duration;

use crate::coordinator::{Decision, StopReason};

/// Frame magic: first four bytes of every frame.
pub const MAGIC: [u8; 4] = *b"BMWP";
/// Protocol version carried in byte 4.
pub const VERSION: u8 = 1;
/// Fixed header length in bytes.
pub const HEADER_LEN: usize = 12;
/// Hard cap on payload length — decode rejects anything larger before
/// allocating, so a hostile length prefix cannot balloon memory.
pub const MAX_PAYLOAD: u32 = 1 << 20;
/// Hard cap on the tenant-id field.
pub const MAX_TENANT_LEN: usize = 64;
/// Hard cap on `DecideBatch` arity (both directions).
pub const MAX_WIRE_BATCH: usize = 4096;

/// Frame type codes (request frames are `0x0n`, responses `0x8n`).
mod ftype {
    pub const PREPARE: u8 = 0x01;
    pub const DECIDE: u8 = 0x02;
    pub const DECIDE_BATCH: u8 = 0x03;
    pub const METRICS: u8 = 0x04;
    pub const SHUTDOWN: u8 = 0x05;
    pub const PREPARED: u8 = 0x81;
    pub const DECISION: u8 = 0x82;
    pub const DECISION_BATCH: u8 = 0x83;
    pub const METRICS_TEXT: u8 = 0x84;
    pub const SHUTDOWN_ACK: u8 = 0x85;
    pub const ERROR: u8 = 0xFF;
}

/// Typed error codes carried by [`Frame::Error`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u16)]
pub enum ErrorCode {
    /// Payload failed strict decode (or a response frame was sent as a
    /// request). The stream stays aligned.
    Malformed = 1,
    /// Header version byte did not match [`VERSION`].
    WrongVersion = 2,
    /// Declared payload or tenant length exceeded the protocol caps.
    Oversized = 3,
    /// Unknown frame type code (payload was consumed; stream aligned).
    UnknownFrame = 4,
    /// Decide referenced a plan id this tenant never prepared.
    UnknownPlan = 5,
    /// Empty or otherwise unusable tenant id.
    UnknownTenant = 6,
    /// Tenant plan or in-flight quota exhausted.
    QuotaExhausted = 7,
    /// Shed-policy admission queue was full.
    Backpressure = 8,
    /// Decision missed its deadline.
    Deadline = 9,
    /// Request failed validation at admission.
    Rejected = 10,
    /// Server (or its coordinator shard) is shutting down.
    Shutdown = 11,
    /// Anything else — the message says what.
    Internal = 12,
}

impl ErrorCode {
    /// Decode from the wire representation.
    pub fn from_u16(v: u16) -> Option<Self> {
        Some(match v {
            1 => ErrorCode::Malformed,
            2 => ErrorCode::WrongVersion,
            3 => ErrorCode::Oversized,
            4 => ErrorCode::UnknownFrame,
            5 => ErrorCode::UnknownPlan,
            6 => ErrorCode::UnknownTenant,
            7 => ErrorCode::QuotaExhausted,
            8 => ErrorCode::Backpressure,
            9 => ErrorCode::Deadline,
            10 => ErrorCode::Rejected,
            11 => ErrorCode::Shutdown,
            12 => ErrorCode::Internal,
            _ => return None,
        })
    }

    /// Stable lowercase name (used in error messages and reports).
    pub fn name(self) -> &'static str {
        match self {
            ErrorCode::Malformed => "malformed",
            ErrorCode::WrongVersion => "wrong-version",
            ErrorCode::Oversized => "oversized",
            ErrorCode::UnknownFrame => "unknown-frame",
            ErrorCode::UnknownPlan => "unknown-plan",
            ErrorCode::UnknownTenant => "unknown-tenant",
            ErrorCode::QuotaExhausted => "quota-exhausted",
            ErrorCode::Backpressure => "backpressure",
            ErrorCode::Deadline => "deadline",
            ErrorCode::Rejected => "rejected",
            ErrorCode::Shutdown => "shutdown",
            ErrorCode::Internal => "internal",
        }
    }
}

/// Decode/transport failure. Every variant is a typed rejection — the
/// codec never panics and never allocates past [`MAX_PAYLOAD`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum WireError {
    /// The peer closed the connection cleanly between frames.
    Closed,
    /// First four bytes were not [`MAGIC`].
    BadMagic([u8; 4]),
    /// Version byte mismatch.
    WrongVersion(u8),
    /// Unknown frame type code.
    UnknownFrameType(u8),
    /// Declared payload/tenant length exceeds protocol caps.
    Oversized {
        /// Length the header declared.
        declared: u32,
        /// The protocol cap it exceeded.
        max: u32,
    },
    /// The stream ended mid-frame.
    Truncated,
    /// Frame was well-framed but the payload failed strict decode.
    Malformed(String),
    /// Underlying socket/stream error.
    Io(String),
}

impl WireError {
    /// Error frame code for this failure.
    pub fn code(&self) -> ErrorCode {
        match self {
            WireError::Closed | WireError::Io(_) | WireError::Truncated => ErrorCode::Internal,
            WireError::BadMagic(_) => ErrorCode::Malformed,
            WireError::WrongVersion(_) => ErrorCode::WrongVersion,
            WireError::UnknownFrameType(_) => ErrorCode::UnknownFrame,
            WireError::Oversized { .. } => ErrorCode::Oversized,
            WireError::Malformed(_) => ErrorCode::Malformed,
        }
    }

    /// `true` when the stream is still frame-aligned after this error
    /// (the offending frame's bytes were fully consumed), so the
    /// connection can answer with an error frame and keep serving.
    /// Desynchronized or transport-level failures must close.
    pub fn recoverable(&self) -> bool {
        matches!(self, WireError::UnknownFrameType(_) | WireError::Malformed(_))
    }
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Closed => write!(f, "connection closed"),
            WireError::BadMagic(m) => write!(f, "bad magic {m:02x?}"),
            WireError::WrongVersion(v) => {
                write!(f, "unsupported protocol version {v} (want {VERSION})")
            }
            WireError::UnknownFrameType(t) => write!(f, "unknown frame type {t:#04x}"),
            WireError::Oversized { declared, max } => {
                write!(f, "declared length {declared} exceeds cap {max}")
            }
            WireError::Truncated => write!(f, "stream ended mid-frame"),
            WireError::Malformed(msg) => write!(f, "malformed payload: {msg}"),
            WireError::Io(msg) => write!(f, "io: {msg}"),
        }
    }
}

impl From<WireError> for crate::Error {
    fn from(e: WireError) -> Self {
        crate::Error::Wire(e.to_string())
    }
}

impl From<io::Error> for WireError {
    fn from(e: io::Error) -> Self {
        if e.kind() == io::ErrorKind::UnexpectedEof {
            WireError::Truncated
        } else {
            WireError::Io(e.to_string())
        }
    }
}

/// Plan specification as it travels over the wire. Network plans carry
/// their spec as TOML text (the on-disk `specs/*.toml` format) so the
/// server compiles them with the same parser as the CLI.
#[derive(Debug, Clone, PartialEq)]
pub enum WireSpec {
    /// Single-cue Bayes update.
    Inference,
    /// Multi-cue fusion of `modalities` posteriors.
    Fusion {
        /// Fusion arity.
        modalities: u32,
    },
    /// Compiled Bayesian-network query.
    Network {
        /// Network spec, TOML source text.
        spec_toml: String,
        /// Queried node name.
        query: String,
        /// Observed `(node, value)` evidence.
        evidence: Vec<(String, bool)>,
    },
}

/// Per-plan decision policy as it travels over the wire (the encoded
/// form of [`crate::coordinator::Policy`]).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct WirePolicy {
    /// Timeliness budget in microseconds.
    pub deadline_us: Option<u64>,
    /// Stream-length override.
    pub bits: Option<u32>,
    /// Reliable-stop decision threshold.
    pub threshold: Option<f64>,
    /// Converged-stop half-width target.
    pub max_half_width: Option<f64>,
    /// Answer best-so-far on deadline instead of erroring.
    pub allow_partial: bool,
}

impl WirePolicy {
    /// Lower to the coordinator's [`crate::coordinator::Policy`].
    pub fn to_policy(self) -> crate::coordinator::Policy {
        crate::coordinator::Policy {
            deadline: self.deadline_us.map(Duration::from_micros),
            bits: self.bits.map(|b| b as usize),
            threshold: self.threshold,
            max_half_width: self.max_half_width,
            allow_partial: self.allow_partial,
        }
    }
}

/// Per-decision parameters as they travel over the wire.
#[derive(Debug, Clone, PartialEq)]
pub enum WireParams {
    /// Prior + likelihoods for a single-cue update.
    Inference {
        /// P(H).
        prior: f64,
        /// P(E|H).
        likelihood: f64,
        /// P(E|¬H).
        likelihood_not: f64,
    },
    /// Per-modality posteriors for a fusion plan.
    Fusion {
        /// One posterior per modality.
        posteriors: Vec<f64>,
    },
    /// Per-decision CPT overrides against a network plan's parameter
    /// table. Empty = serve the baked (prepare-time) bindings; each
    /// entry is `(node, cpt_row, probability)`. Capped at
    /// [`crate::coordinator::MAX_NETWORK_OVERRIDES`] on decode.
    Network {
        /// `(node, cpt_row, probability)` rebindings.
        overrides: Vec<(String, u32, f64)>,
    },
}

impl WireParams {
    /// Lower to the coordinator's [`crate::coordinator::DecisionParams`].
    pub fn to_params(&self) -> crate::coordinator::DecisionParams {
        match self {
            WireParams::Inference { prior, likelihood, likelihood_not } => {
                crate::coordinator::DecisionParams::Inference {
                    prior: *prior,
                    likelihood: *likelihood,
                    likelihood_not: *likelihood_not,
                }
            }
            WireParams::Fusion { posteriors } => {
                crate::coordinator::DecisionParams::Fusion { posteriors: posteriors.clone() }
            }
            WireParams::Network { overrides } => crate::coordinator::DecisionParams::Network {
                overrides: overrides
                    .iter()
                    .map(|(node, row, value)| {
                        crate::coordinator::NetworkOverride::new(node.clone(), *row, *value)
                    })
                    .collect(),
            },
        }
    }
}

/// A served decision as it travels over the wire.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WireDecision {
    /// Server-side request id.
    pub id: u64,
    /// Stochastic posterior read out from the netlist sweep.
    pub posterior: f64,
    /// Closed-form reference posterior.
    pub exact: f64,
    /// End-to-end latency observed by the shard, nanoseconds.
    pub latency_ns: u64,
    /// Stochastic bits actually streamed.
    pub bits_used: u64,
    /// Wilson half-width at `bits_used`.
    pub confidence: f64,
    /// Stop reason code (see [`stop_code`]).
    pub stop: u8,
    /// Size of the dynamic batch the decision rode in.
    pub batch_size: u32,
}

impl WireDecision {
    /// Build from a coordinator [`Decision`].
    pub fn from_decision(d: &Decision) -> Self {
        WireDecision {
            id: d.id,
            posterior: d.posterior,
            exact: d.exact,
            latency_ns: d.latency.as_nanos().min(u64::MAX as u128) as u64,
            bits_used: d.bits_used as u64,
            confidence: d.confidence,
            stop: stop_code(d.stop),
            batch_size: d.batch_size as u32,
        }
    }

    /// Decode the stop-reason code.
    pub fn stop_reason(&self) -> Option<StopReason> {
        stop_from_code(self.stop)
    }
}

/// [`StopReason`] → wire code.
pub fn stop_code(stop: StopReason) -> u8 {
    match stop {
        StopReason::Exhausted => 0,
        StopReason::Reliable => 1,
        StopReason::Converged => 2,
        StopReason::Timely => 3,
    }
}

/// Wire code → [`StopReason`].
pub fn stop_from_code(code: u8) -> Option<StopReason> {
    Some(match code {
        0 => StopReason::Exhausted,
        1 => StopReason::Reliable,
        2 => StopReason::Converged,
        3 => StopReason::Timely,
        _ => return None,
    })
}

/// One protocol frame (request or response).
#[derive(Debug, Clone, PartialEq)]
pub enum Frame {
    /// Compile a plan into the tenant's namespace.
    Prepare {
        /// What to compile.
        spec: WireSpec,
        /// Policy applied to every decision on the plan.
        policy: WirePolicy,
    },
    /// One decision against a prepared plan.
    Decide {
        /// Tenant-scoped plan id from [`Frame::Prepared`].
        plan: u32,
        /// Per-decision parameters.
        params: WireParams,
    },
    /// A batch of decisions against one plan, answered in order.
    DecideBatch {
        /// Tenant-scoped plan id.
        plan: u32,
        /// One entry per decision.
        params: Vec<WireParams>,
    },
    /// Fetch the tenant's metrics exposition.
    Metrics,
    /// Ask the server to shut down.
    Shutdown,
    /// Prepare succeeded.
    Prepared {
        /// Tenant-scoped plan id to decide against.
        plan: u32,
    },
    /// Decide succeeded.
    Decision(WireDecision),
    /// DecideBatch response: one entry per request, in order; failed
    /// entries carry their typed code + message.
    DecisionBatch(Vec<std::result::Result<WireDecision, (ErrorCode, String)>>),
    /// Metrics response (Prometheus-style text).
    MetricsText(String),
    /// Shutdown acknowledged; the server stops accepting.
    ShutdownAck,
    /// Typed failure for the preceding request frame.
    Error {
        /// What class of failure.
        code: ErrorCode,
        /// Human-readable detail.
        message: String,
    },
}

impl Frame {
    /// Wire code for this frame's type.
    pub fn frame_type(&self) -> u8 {
        match self {
            Frame::Prepare { .. } => ftype::PREPARE,
            Frame::Decide { .. } => ftype::DECIDE,
            Frame::DecideBatch { .. } => ftype::DECIDE_BATCH,
            Frame::Metrics => ftype::METRICS,
            Frame::Shutdown => ftype::SHUTDOWN,
            Frame::Prepared { .. } => ftype::PREPARED,
            Frame::Decision(_) => ftype::DECISION,
            Frame::DecisionBatch(_) => ftype::DECISION_BATCH,
            Frame::MetricsText(_) => ftype::METRICS_TEXT,
            Frame::ShutdownAck => ftype::SHUTDOWN_ACK,
            Frame::Error { .. } => ftype::ERROR,
        }
    }

    /// `true` for the request half of the protocol.
    pub fn is_request(&self) -> bool {
        self.frame_type() < 0x80
    }

    /// Encode `self` (with `tenant` in the header) into one contiguous
    /// frame. Fails if the tenant id or encoded payload exceeds the
    /// protocol caps.
    pub fn encode(&self, tenant: &str) -> Result<Vec<u8>, WireError> {
        if tenant.len() > MAX_TENANT_LEN {
            return Err(WireError::Oversized {
                declared: tenant.len() as u32,
                max: MAX_TENANT_LEN as u32,
            });
        }
        let payload = self.encode_payload();
        if payload.len() > MAX_PAYLOAD as usize {
            return Err(WireError::Oversized { declared: payload.len() as u32, max: MAX_PAYLOAD });
        }
        let mut out = Vec::with_capacity(HEADER_LEN + tenant.len() + payload.len());
        out.extend_from_slice(&MAGIC);
        out.push(VERSION);
        out.push(self.frame_type());
        out.push(tenant.len() as u8);
        out.push(0); // reserved
        out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        out.extend_from_slice(tenant.as_bytes());
        out.extend_from_slice(&payload);
        Ok(out)
    }

    fn encode_payload(&self) -> Vec<u8> {
        let mut p = Vec::new();
        match self {
            Frame::Prepare { spec, policy } => {
                match spec {
                    WireSpec::Inference => p.push(0),
                    WireSpec::Fusion { modalities } => {
                        p.push(1);
                        put_u32(&mut p, *modalities);
                    }
                    WireSpec::Network { spec_toml, query, evidence } => {
                        p.push(2);
                        put_str(&mut p, spec_toml);
                        put_str(&mut p, query);
                        put_u32(&mut p, evidence.len() as u32);
                        for (node, value) in evidence {
                            put_str(&mut p, node);
                            p.push(u8::from(*value));
                        }
                    }
                }
                put_policy(&mut p, policy);
            }
            Frame::Decide { plan, params } => {
                put_u32(&mut p, *plan);
                put_params(&mut p, params);
            }
            Frame::DecideBatch { plan, params } => {
                put_u32(&mut p, *plan);
                put_u32(&mut p, params.len() as u32);
                for item in params {
                    put_params(&mut p, item);
                }
            }
            Frame::Metrics | Frame::Shutdown | Frame::ShutdownAck => {}
            Frame::Prepared { plan } => put_u32(&mut p, *plan),
            Frame::Decision(d) => put_decision(&mut p, d),
            Frame::DecisionBatch(items) => {
                put_u32(&mut p, items.len() as u32);
                for item in items {
                    match item {
                        Ok(d) => {
                            p.push(1);
                            put_decision(&mut p, d);
                        }
                        Err((code, message)) => {
                            p.push(0);
                            put_u16(&mut p, *code as u16);
                            put_str(&mut p, message);
                        }
                    }
                }
            }
            Frame::MetricsText(text) => put_str(&mut p, text),
            Frame::Error { code, message } => {
                put_u16(&mut p, *code as u16);
                put_str(&mut p, message);
            }
        }
        p
    }

    /// Strict payload decode for a known frame type. Every read is
    /// bounds-checked; trailing bytes are malformed.
    pub fn decode(ftype_code: u8, payload: &[u8]) -> Result<Frame, WireError> {
        let mut c = Cursor::new(payload);
        let frame = match ftype_code {
            ftype::PREPARE => {
                let spec = match c.u8()? {
                    0 => WireSpec::Inference,
                    1 => WireSpec::Fusion { modalities: c.u32()? },
                    2 => {
                        let spec_toml = c.str()?;
                        let query = c.str()?;
                        let n = c.len_capped(MAX_WIRE_BATCH, "evidence")?;
                        let mut evidence = Vec::with_capacity(n);
                        for _ in 0..n {
                            let node = c.str()?;
                            let value = match c.u8()? {
                                0 => false,
                                1 => true,
                                v => {
                                    return Err(WireError::Malformed(format!(
                                        "evidence value byte {v}"
                                    )))
                                }
                            };
                            evidence.push((node, value));
                        }
                        WireSpec::Network { spec_toml, query, evidence }
                    }
                    t => return Err(WireError::Malformed(format!("spec tag {t}"))),
                };
                Frame::Prepare { spec, policy: get_policy(&mut c)? }
            }
            ftype::DECIDE => Frame::Decide { plan: c.u32()?, params: get_params(&mut c)? },
            ftype::DECIDE_BATCH => {
                let plan = c.u32()?;
                let n = c.len_capped(MAX_WIRE_BATCH, "batch")?;
                let mut params = Vec::with_capacity(n);
                for _ in 0..n {
                    params.push(get_params(&mut c)?);
                }
                Frame::DecideBatch { plan, params }
            }
            ftype::METRICS => Frame::Metrics,
            ftype::SHUTDOWN => Frame::Shutdown,
            ftype::PREPARED => Frame::Prepared { plan: c.u32()? },
            ftype::DECISION => Frame::Decision(get_decision(&mut c)?),
            ftype::DECISION_BATCH => {
                let n = c.len_capped(MAX_WIRE_BATCH, "batch")?;
                let mut items = Vec::with_capacity(n);
                for _ in 0..n {
                    items.push(match c.u8()? {
                        1 => Ok(get_decision(&mut c)?),
                        0 => {
                            let code = get_code(&mut c)?;
                            Err((code, c.str()?))
                        }
                        v => return Err(WireError::Malformed(format!("result tag {v}"))),
                    });
                }
                Frame::DecisionBatch(items)
            }
            ftype::METRICS_TEXT => Frame::MetricsText(c.str()?),
            ftype::SHUTDOWN_ACK => Frame::ShutdownAck,
            ftype::ERROR => {
                let code = get_code(&mut c)?;
                Frame::Error { code, message: c.str()? }
            }
            t => return Err(WireError::UnknownFrameType(t)),
        };
        c.finish()?;
        Ok(frame)
    }
}

/// Write one frame to `w` (single buffered write).
pub fn write_frame(w: &mut impl Write, tenant: &str, frame: &Frame) -> Result<(), WireError> {
    let bytes = frame.encode(tenant)?;
    w.write_all(&bytes)?;
    w.flush()?;
    Ok(())
}

/// Read one frame from `r`, returning `(tenant, frame)`.
///
/// A clean close **between** frames is [`WireError::Closed`]; a close
/// mid-frame is [`WireError::Truncated`]. Oversized declared lengths
/// are rejected before any payload allocation. An unknown frame type
/// or undecodable payload still consumes the whole frame, so those
/// errors leave the stream aligned ([`WireError::recoverable`]).
pub fn read_frame(r: &mut impl Read) -> Result<(String, Frame), WireError> {
    let mut header = [0u8; HEADER_LEN];
    // First byte separately: 0 bytes here is a clean close, not a
    // truncation.
    let n = r.read(&mut header[..1])?;
    if n == 0 {
        return Err(WireError::Closed);
    }
    r.read_exact(&mut header[1..])?;
    if header[..4] != MAGIC {
        return Err(WireError::BadMagic([header[0], header[1], header[2], header[3]]));
    }
    if header[4] != VERSION {
        return Err(WireError::WrongVersion(header[4]));
    }
    let ftype_code = header[5];
    let tenant_len = header[6] as usize;
    if tenant_len > MAX_TENANT_LEN {
        return Err(WireError::Oversized {
            declared: tenant_len as u32,
            max: MAX_TENANT_LEN as u32,
        });
    }
    if header[7] != 0 {
        return Err(WireError::Malformed(format!("reserved byte {}", header[7])));
    }
    let payload_len = u32::from_le_bytes([header[8], header[9], header[10], header[11]]);
    if payload_len > MAX_PAYLOAD {
        return Err(WireError::Oversized { declared: payload_len, max: MAX_PAYLOAD });
    }
    let mut tenant_bytes = vec![0u8; tenant_len];
    r.read_exact(&mut tenant_bytes)?;
    let mut payload = vec![0u8; payload_len as usize];
    r.read_exact(&mut payload)?;
    // From here on the frame is fully consumed: failures are typed but
    // the stream stays aligned.
    let tenant = String::from_utf8(tenant_bytes)
        .map_err(|_| WireError::Malformed("tenant id is not UTF-8".into()))?;
    let frame = Frame::decode(ftype_code, &payload)?;
    Ok((tenant, frame))
}

// ---------------------------------------------------------------- codec

fn put_u16(p: &mut Vec<u8>, v: u16) {
    p.extend_from_slice(&v.to_le_bytes());
}

fn put_u32(p: &mut Vec<u8>, v: u32) {
    p.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(p: &mut Vec<u8>, v: u64) {
    p.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(p: &mut Vec<u8>, v: f64) {
    p.extend_from_slice(&v.to_bits().to_le_bytes());
}

fn put_str(p: &mut Vec<u8>, s: &str) {
    put_u32(p, s.len() as u32);
    p.extend_from_slice(s.as_bytes());
}

fn put_opt_u64(p: &mut Vec<u8>, v: Option<u64>) {
    match v {
        Some(v) => {
            p.push(1);
            put_u64(p, v);
        }
        None => p.push(0),
    }
}

fn put_opt_f64(p: &mut Vec<u8>, v: Option<f64>) {
    match v {
        Some(v) => {
            p.push(1);
            put_f64(p, v);
        }
        None => p.push(0),
    }
}

fn put_policy(p: &mut Vec<u8>, policy: &WirePolicy) {
    put_opt_u64(p, policy.deadline_us);
    put_opt_u64(p, policy.bits.map(u64::from));
    put_opt_f64(p, policy.threshold);
    put_opt_f64(p, policy.max_half_width);
    p.push(u8::from(policy.allow_partial));
}

fn put_params(p: &mut Vec<u8>, params: &WireParams) {
    match params {
        WireParams::Inference { prior, likelihood, likelihood_not } => {
            p.push(0);
            put_f64(p, *prior);
            put_f64(p, *likelihood);
            put_f64(p, *likelihood_not);
        }
        WireParams::Fusion { posteriors } => {
            p.push(1);
            put_u32(p, posteriors.len() as u32);
            for v in posteriors {
                put_f64(p, *v);
            }
        }
        WireParams::Network { overrides } => {
            p.push(2);
            put_u32(p, overrides.len() as u32);
            for (node, row, value) in overrides {
                put_str(p, node);
                put_u32(p, *row);
                put_f64(p, *value);
            }
        }
    }
}

fn put_decision(p: &mut Vec<u8>, d: &WireDecision) {
    put_u64(p, d.id);
    put_f64(p, d.posterior);
    put_f64(p, d.exact);
    put_u64(p, d.latency_ns);
    put_u64(p, d.bits_used);
    put_f64(p, d.confidence);
    p.push(d.stop);
    put_u32(p, d.batch_size);
}

/// Bounds-checked payload reader: every accessor verifies the remaining
/// length before touching the buffer, so garbage input can only yield
/// typed [`WireError`]s.
struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Cursor { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        if self.buf.len() - self.pos < n {
            return Err(WireError::Truncated);
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16, WireError> {
        let b = self.take(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    fn u32(&mut self) -> Result<u32, WireError> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64, WireError> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    fn f64(&mut self) -> Result<f64, WireError> {
        Ok(f64::from_bits(self.u64()?))
    }

    /// Length-prefixed count, capped both by `cap` and by the bytes
    /// actually remaining (each element is ≥ 1 byte), so a hostile
    /// count cannot drive a large `Vec::with_capacity`.
    fn len_capped(&mut self, cap: usize, what: &str) -> Result<usize, WireError> {
        let n = self.u32()? as usize;
        if n > cap {
            return Err(WireError::Malformed(format!("{what} count {n} exceeds cap {cap}")));
        }
        if n > self.buf.len() - self.pos {
            return Err(WireError::Truncated);
        }
        Ok(n)
    }

    fn str(&mut self) -> Result<String, WireError> {
        let n = self.u32()? as usize;
        let bytes = self.take(n)?;
        String::from_utf8(bytes.to_vec())
            .map_err(|_| WireError::Malformed("string is not UTF-8".into()))
    }

    fn finish(&self) -> Result<(), WireError> {
        if self.pos == self.buf.len() {
            Ok(())
        } else {
            Err(WireError::Malformed(format!(
                "{} trailing bytes after payload",
                self.buf.len() - self.pos
            )))
        }
    }
}

fn get_code(c: &mut Cursor<'_>) -> Result<ErrorCode, WireError> {
    let raw = c.u16()?;
    ErrorCode::from_u16(raw).ok_or_else(|| WireError::Malformed(format!("error code {raw}")))
}

fn get_opt_u64(c: &mut Cursor<'_>) -> Result<Option<u64>, WireError> {
    match c.u8()? {
        0 => Ok(None),
        1 => Ok(Some(c.u64()?)),
        v => Err(WireError::Malformed(format!("option tag {v}"))),
    }
}

fn get_opt_f64(c: &mut Cursor<'_>) -> Result<Option<f64>, WireError> {
    match c.u8()? {
        0 => Ok(None),
        1 => Ok(Some(c.f64()?)),
        v => Err(WireError::Malformed(format!("option tag {v}"))),
    }
}

fn get_policy(c: &mut Cursor<'_>) -> Result<WirePolicy, WireError> {
    let deadline_us = get_opt_u64(c)?;
    let bits = match get_opt_u64(c)? {
        Some(v) if v > u32::MAX as u64 => {
            return Err(WireError::Malformed(format!("bits {v} exceeds u32")))
        }
        Some(v) => Some(v as u32),
        None => None,
    };
    let threshold = get_opt_f64(c)?;
    let max_half_width = get_opt_f64(c)?;
    let allow_partial = match c.u8()? {
        0 => false,
        1 => true,
        v => return Err(WireError::Malformed(format!("bool byte {v}"))),
    };
    Ok(WirePolicy { deadline_us, bits, threshold, max_half_width, allow_partial })
}

fn get_params(c: &mut Cursor<'_>) -> Result<WireParams, WireError> {
    match c.u8()? {
        0 => Ok(WireParams::Inference {
            prior: c.f64()?,
            likelihood: c.f64()?,
            likelihood_not: c.f64()?,
        }),
        1 => {
            let n = c.len_capped(MAX_WIRE_BATCH, "posteriors")?;
            let mut posteriors = Vec::with_capacity(n);
            for _ in 0..n {
                posteriors.push(c.f64()?);
            }
            Ok(WireParams::Fusion { posteriors })
        }
        2 => {
            let n = c.len_capped(crate::coordinator::MAX_NETWORK_OVERRIDES, "overrides")?;
            let mut overrides = Vec::with_capacity(n);
            for _ in 0..n {
                let node = c.str()?;
                let row = c.u32()?;
                let value = c.f64()?;
                overrides.push((node, row, value));
            }
            Ok(WireParams::Network { overrides })
        }
        t => Err(WireError::Malformed(format!("params tag {t}"))),
    }
}

fn get_decision(c: &mut Cursor<'_>) -> Result<WireDecision, WireError> {
    Ok(WireDecision {
        id: c.u64()?,
        posterior: c.f64()?,
        exact: c.f64()?,
        latency_ns: c.u64()?,
        bits_used: c.u64()?,
        confidence: c.f64()?,
        stop: c.u8()?,
        batch_size: c.u32()?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest_lite;
    use crate::util::Rng;

    fn roundtrip(frame: &Frame, tenant: &str) {
        let bytes = frame.encode(tenant).expect("encode");
        let mut r = io::Cursor::new(bytes);
        let (t, decoded) = read_frame(&mut r).expect("decode");
        assert_eq!(t, tenant);
        assert_eq!(&decoded, frame);
    }

    fn arb_string(rng: &mut Rng, max_len: usize) -> String {
        let n = rng.range_usize(0, max_len + 1);
        (0..n).map(|_| char::from(b'a' + (rng.next_u64() % 26) as u8)).collect()
    }

    fn arb_policy(rng: &mut Rng) -> WirePolicy {
        WirePolicy {
            deadline_us: (rng.f64() > 0.5).then(|| rng.next_u64() % 1_000_000),
            bits: (rng.f64() > 0.5).then(|| (rng.next_u64() % (1 << 20)) as u32),
            threshold: (rng.f64() > 0.5).then(|| rng.f64()),
            max_half_width: (rng.f64() > 0.5).then(|| rng.f64()),
            allow_partial: rng.f64() > 0.5,
        }
    }

    fn arb_params(rng: &mut Rng) -> WireParams {
        match rng.next_u64() % 3 {
            0 => WireParams::Inference {
                prior: rng.f64(),
                likelihood: rng.f64(),
                likelihood_not: rng.f64(),
            },
            1 => {
                let n = rng.range_usize(1, 9);
                WireParams::Fusion { posteriors: (0..n).map(|_| rng.f64()).collect() }
            }
            _ => WireParams::Network {
                overrides: (0..rng.range_usize(0, 4))
                    .map(|_| (arb_string(rng, 8), (rng.next_u64() % 8) as u32, rng.f64()))
                    .collect(),
            },
        }
    }

    fn arb_decision(rng: &mut Rng) -> WireDecision {
        WireDecision {
            id: rng.next_u64(),
            posterior: rng.f64(),
            exact: rng.f64(),
            latency_ns: rng.next_u64() % (1 << 40),
            bits_used: rng.next_u64() % (1 << 24),
            confidence: rng.f64(),
            stop: (rng.next_u64() % 4) as u8,
            batch_size: (rng.next_u64() % 64) as u32,
        }
    }

    fn arb_frame(rng: &mut Rng) -> Frame {
        match rng.next_u64() % 11 {
            0 => {
                let spec = match rng.next_u64() % 3 {
                    0 => WireSpec::Inference,
                    1 => WireSpec::Fusion { modalities: 1 + (rng.next_u64() % 16) as u32 },
                    _ => WireSpec::Network {
                        spec_toml: arb_string(rng, 64),
                        query: arb_string(rng, 16),
                        evidence: (0..rng.range_usize(0, 4))
                            .map(|_| (arb_string(rng, 8), rng.f64() > 0.5))
                            .collect(),
                    },
                };
                Frame::Prepare { spec, policy: arb_policy(rng) }
            }
            1 => Frame::Decide { plan: rng.next_u64() as u32, params: arb_params(rng) },
            2 => Frame::DecideBatch {
                plan: rng.next_u64() as u32,
                params: (0..rng.range_usize(0, 8)).map(|_| arb_params(rng)).collect(),
            },
            3 => Frame::Metrics,
            4 => Frame::Shutdown,
            5 => Frame::Prepared { plan: rng.next_u64() as u32 },
            6 => Frame::Decision(arb_decision(rng)),
            7 => Frame::DecisionBatch(
                (0..rng.range_usize(0, 6))
                    .map(|_| {
                        if rng.f64() > 0.3 {
                            Ok(arb_decision(rng))
                        } else {
                            Err((ErrorCode::Rejected, arb_string(rng, 24)))
                        }
                    })
                    .collect(),
            ),
            8 => Frame::MetricsText(arb_string(rng, 200)),
            9 => Frame::ShutdownAck,
            _ => Frame::Error { code: ErrorCode::UnknownPlan, message: arb_string(rng, 32) },
        }
    }

    #[test]
    fn every_frame_type_round_trips() {
        // One deterministic instance of each frame type first...
        let frames = [
            Frame::Prepare {
                spec: WireSpec::Network {
                    spec_toml: "[net]\nname = \"x\"".into(),
                    query: "hazard".into(),
                    evidence: vec![("alarm".into(), true), ("vis".into(), false)],
                },
                policy: WirePolicy {
                    deadline_us: Some(400),
                    bits: Some(4096),
                    threshold: Some(0.7),
                    max_half_width: None,
                    allow_partial: true,
                },
            },
            Frame::Decide {
                plan: 3,
                params: WireParams::Inference {
                    prior: 0.57,
                    likelihood: 0.77,
                    likelihood_not: 0.655,
                },
            },
            Frame::DecideBatch {
                plan: 9,
                params: vec![
                    WireParams::Fusion { posteriors: vec![0.8, 0.7] },
                    WireParams::Network { overrides: vec![] },
                    WireParams::Network {
                        overrides: vec![("hazard".into(), 0, 0.42), ("fog".into(), 1, 0.9)],
                    },
                ],
            },
            Frame::Metrics,
            Frame::Shutdown,
            Frame::Prepared { plan: 42 },
            Frame::Decision(WireDecision {
                id: 7,
                posterior: 0.61,
                exact: 0.609,
                latency_ns: 123_456,
                bits_used: 4096,
                confidence: 0.01,
                stop: 1,
                batch_size: 4,
            }),
            Frame::DecisionBatch(vec![
                Ok(WireDecision {
                    id: 1,
                    posterior: 0.5,
                    exact: 0.5,
                    latency_ns: 10,
                    bits_used: 64,
                    confidence: 0.1,
                    stop: 0,
                    batch_size: 1,
                }),
                Err((ErrorCode::Deadline, "missed".into())),
            ]),
            Frame::MetricsText("tenant_decisions_completed_total 3\n".into()),
            Frame::ShutdownAck,
            Frame::Error { code: ErrorCode::Backpressure, message: "queue full".into() },
        ];
        for frame in &frames {
            roundtrip(frame, "tenant-a");
            roundtrip(frame, "");
        }
    }

    #[test]
    fn random_frames_round_trip() {
        proptest_lite::check("wire_roundtrip", 400, |rng| {
            let frame = arb_frame(rng);
            let tenant = arb_string(rng, MAX_TENANT_LEN);
            roundtrip(&frame, &tenant);
        });
    }

    #[test]
    fn garbage_never_panics_and_is_typed() {
        proptest_lite::check("wire_garbage", 600, |rng| {
            let n = rng.range_usize(0, 64);
            let bytes: Vec<u8> = (0..n).map(|_| (rng.next_u64() & 0xFF) as u8).collect();
            let mut r = io::Cursor::new(bytes);
            // Any outcome but a panic is fine; empty input must be a
            // clean close.
            let _ = read_frame(&mut r);
        });
        let mut empty = io::Cursor::new(Vec::new());
        assert_eq!(read_frame(&mut empty).unwrap_err(), WireError::Closed);
    }

    #[test]
    fn corrupted_valid_frames_never_panic() {
        // Flip bytes inside otherwise-valid frames: decode must stay
        // typed (this walks the payload decoders, not just the header).
        proptest_lite::check("wire_corruption", 400, |rng| {
            let mut bytes = arb_frame(rng).encode("t").expect("encode");
            let flips = rng.range_usize(1, 4);
            for _ in 0..flips {
                let i = rng.range_usize(0, bytes.len());
                bytes[i] ^= (rng.next_u64() & 0xFF) as u8;
            }
            let mut r = io::Cursor::new(bytes);
            let _ = read_frame(&mut r);
        });
    }

    #[test]
    fn oversized_frames_are_rejected_before_allocation() {
        // Declared payload length far beyond the cap: the error must
        // come from the header check (no payload read, no allocation).
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&MAGIC);
        bytes.push(VERSION);
        bytes.push(0x02);
        bytes.push(0);
        bytes.push(0);
        bytes.extend_from_slice(&u32::MAX.to_le_bytes());
        let mut r = io::Cursor::new(bytes);
        assert_eq!(
            read_frame(&mut r).unwrap_err(),
            WireError::Oversized { declared: u32::MAX, max: MAX_PAYLOAD }
        );

        // Oversized tenant length likewise.
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&MAGIC);
        bytes.push(VERSION);
        bytes.push(0x02);
        bytes.push(200);
        bytes.push(0);
        bytes.extend_from_slice(&0u32.to_le_bytes());
        let mut r = io::Cursor::new(bytes);
        assert!(matches!(read_frame(&mut r).unwrap_err(), WireError::Oversized { .. }));
    }

    #[test]
    fn truncated_and_wrong_version_frames_are_typed() {
        let full = Frame::Metrics.encode("t").unwrap();
        for cut in 1..full.len() {
            let mut r = io::Cursor::new(full[..cut].to_vec());
            let err = read_frame(&mut r).unwrap_err();
            assert_eq!(err, WireError::Truncated, "cut at {cut}");
            assert!(!err.recoverable());
        }

        let mut versioned = full.clone();
        versioned[4] = 99;
        let mut r = io::Cursor::new(versioned);
        assert_eq!(read_frame(&mut r).unwrap_err(), WireError::WrongVersion(99));

        let mut magicked = full;
        magicked[0] = b'X';
        let mut r = io::Cursor::new(magicked);
        assert!(matches!(read_frame(&mut r).unwrap_err(), WireError::BadMagic(_)));
    }

    #[test]
    fn malformed_payload_is_recoverable_and_consumes_the_frame() {
        // A Decide frame with a bogus params tag: typed error, and the
        // next frame on the same stream still decodes.
        let mut bad = Vec::new();
        bad.extend_from_slice(&MAGIC);
        bad.push(VERSION);
        bad.push(0x02); // Decide
        bad.push(0);
        bad.push(0);
        let payload = {
            let mut p = Vec::new();
            put_u32(&mut p, 7);
            p.push(9); // bogus params tag
            p
        };
        bad.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        bad.extend_from_slice(&payload);
        bad.extend_from_slice(&Frame::Metrics.encode("t").unwrap());
        let mut r = io::Cursor::new(bad);
        let err = read_frame(&mut r).unwrap_err();
        assert!(matches!(err, WireError::Malformed(_)), "{err:?}");
        assert!(err.recoverable());
        let (tenant, frame) = read_frame(&mut r).expect("stream stays aligned");
        assert_eq!(tenant, "t");
        assert_eq!(frame, Frame::Metrics);
    }

    #[test]
    fn unknown_frame_type_is_recoverable() {
        let mut bytes = Frame::Metrics.encode("t").unwrap();
        bytes[5] = 0x66;
        bytes.extend_from_slice(&Frame::Shutdown.encode("t").unwrap());
        let mut r = io::Cursor::new(bytes);
        let err = read_frame(&mut r).unwrap_err();
        assert_eq!(err, WireError::UnknownFrameType(0x66));
        assert!(err.recoverable());
        assert_eq!(read_frame(&mut r).unwrap().1, Frame::Shutdown);
    }

    #[test]
    fn trailing_bytes_are_malformed() {
        let mut bytes = Frame::Prepared { plan: 1 }.encode("t").unwrap();
        // Grow the declared payload by one byte of junk.
        bytes.push(0xAB);
        let len = u32::from_le_bytes([bytes[8], bytes[9], bytes[10], bytes[11]]) + 1;
        bytes[8..12].copy_from_slice(&len.to_le_bytes());
        let mut r = io::Cursor::new(bytes);
        assert!(matches!(read_frame(&mut r).unwrap_err(), WireError::Malformed(_)));
    }

    #[test]
    fn hostile_counts_cannot_balloon_allocation() {
        // A DecideBatch declaring 2^31 entries in a 16-byte payload
        // must fail without reserving capacity for them.
        let mut p = Vec::new();
        put_u32(&mut p, 1);
        put_u32(&mut p, 1 << 31);
        let err = Frame::decode(0x03, &p).unwrap_err();
        assert!(matches!(err, WireError::Malformed(_)), "{err:?}");

        // ... and a count that passes the cap but not the remaining
        // bytes is a truncation, also pre-allocation.
        let mut p = Vec::new();
        put_u32(&mut p, 1);
        put_u32(&mut p, 64);
        assert_eq!(Frame::decode(0x03, &p).unwrap_err(), WireError::Truncated);
    }

    #[test]
    fn hostile_override_fields_decode_to_typed_errors() {
        // A Decide frame declaring 2^30 overrides in a tiny payload:
        // rejected at the count check, before any allocation.
        let mut p = Vec::new();
        put_u32(&mut p, 7); // plan id
        p.push(2); // Network params tag
        put_u32(&mut p, 1 << 30);
        let err = Frame::decode(0x02, &p).unwrap_err();
        assert!(matches!(err, WireError::Malformed(_)), "{err:?}");

        // A count within the cap but past the remaining bytes is a
        // truncation.
        let mut p = Vec::new();
        put_u32(&mut p, 7);
        p.push(2);
        put_u32(&mut p, 64);
        assert_eq!(Frame::decode(0x02, &p).unwrap_err(), WireError::Truncated);

        // An override whose node-name length runs past the payload.
        let mut p = Vec::new();
        put_u32(&mut p, 7);
        p.push(2);
        put_u32(&mut p, 1);
        put_u32(&mut p, 1 << 20); // hostile string length
        assert_eq!(Frame::decode(0x02, &p).unwrap_err(), WireError::Truncated);

        // Non-UTF-8 node names are malformed, not panics.
        let mut p = Vec::new();
        put_u32(&mut p, 7);
        p.push(2);
        put_u32(&mut p, 1);
        put_u32(&mut p, 2);
        p.extend_from_slice(&[0xFF, 0xFE]);
        put_u32(&mut p, 0);
        put_f64(&mut p, 0.5);
        let err = Frame::decode(0x02, &p).unwrap_err();
        assert!(matches!(err, WireError::Malformed(_)), "{err:?}");

        // Random bytes after a valid Network-params prefix: never a
        // panic, always a typed error or a (valid) decode.
        proptest_lite::check("wire_override_fuzz", 400, |rng| {
            let mut p = Vec::new();
            put_u32(&mut p, rng.next_u64() as u32);
            p.push(2);
            let n = rng.range_usize(0, 48);
            for _ in 0..n {
                p.push((rng.next_u64() & 0xFF) as u8);
            }
            let _ = Frame::decode(0x02, &p);
        });
    }

    #[test]
    fn policy_lowering_matches_fields() {
        let wp = WirePolicy {
            deadline_us: Some(400),
            bits: Some(1 << 12),
            threshold: Some(0.7),
            max_half_width: Some(0.05),
            allow_partial: true,
        };
        let p = wp.to_policy();
        assert_eq!(p.deadline, Some(Duration::from_micros(400)));
        assert_eq!(p.bits, Some(1 << 12));
        assert_eq!(p.threshold, Some(0.7));
        assert_eq!(p.max_half_width, Some(0.05));
        assert!(p.allow_partial);
    }

    #[test]
    fn stop_codes_round_trip() {
        for stop in
            [StopReason::Exhausted, StopReason::Reliable, StopReason::Converged, StopReason::Timely]
        {
            assert_eq!(stop_from_code(stop_code(stop)), Some(stop));
        }
        assert_eq!(stop_from_code(99), None);
    }
}
