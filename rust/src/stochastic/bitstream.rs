//! Packed stochastic bitstreams.
//!
//! Bits are packed 64-per-word so gate operations are single bitwise ops
//! over `u64` lanes — this is the software analogue of the paper's
//! bit-parallel hardware and the L3 hot path (see DESIGN.md §6).


use crate::{Error, Result};

/// A fixed-length stream of stochastic bits, LSB-first within each word.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Bitstream {
    words: Vec<u64>,
    len: usize,
}

impl Bitstream {
    /// All-zero stream of `len` bits.
    pub fn zeros(len: usize) -> Self {
        Self { words: vec![0; len.div_ceil(64)], len }
    }

    /// All-one stream of `len` bits.
    pub fn ones(len: usize) -> Self {
        let mut s = Self::zeros(len);
        for w in &mut s.words {
            *w = u64::MAX;
        }
        s.mask_tail();
        s
    }

    /// Build from a bool slice.
    pub fn from_bits(bits: &[bool]) -> Self {
        let mut s = Self::zeros(bits.len());
        for (i, &b) in bits.iter().enumerate() {
            if b {
                s.set(i, true);
            }
        }
        s
    }

    /// Build from raw words (caller guarantees tail bits beyond `len` may
    /// be dirty — they are masked here).
    pub fn from_words(words: Vec<u64>, len: usize) -> Result<Self> {
        if words.len() != len.div_ceil(64) {
            return Err(Error::LengthMismatch { lhs: words.len() * 64, rhs: len });
        }
        let mut s = Self { words, len };
        s.mask_tail();
        Ok(s)
    }

    /// Number of bits.
    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when the stream has zero bits.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Raw packed words.
    #[inline]
    pub fn words(&self) -> &[u64] {
        &self.words
    }

    /// Mutable raw packed words. Callers must not set bits past `len`
    /// (call [`Self::mask_tail`] afterwards if unsure).
    #[inline]
    pub fn words_mut(&mut self) -> &mut [u64] {
        &mut self.words
    }

    /// Clear any bits beyond `len` in the last word.
    pub fn mask_tail(&mut self) {
        if let Some(last) = self.words.last_mut() {
            *last &= tail_word_mask(self.len);
        }
    }

    /// Get bit `i`.
    #[inline]
    pub fn get(&self, i: usize) -> bool {
        debug_assert!(i < self.len);
        (self.words[i / 64] >> (i % 64)) & 1 == 1
    }

    /// Set bit `i`.
    #[inline]
    pub fn set(&mut self, i: usize, v: bool) {
        debug_assert!(i < self.len);
        let (w, b) = (i / 64, i % 64);
        if v {
            self.words[w] |= 1 << b;
        } else {
            self.words[w] &= !(1 << b);
        }
    }

    /// Number of 1 bits.
    pub fn count_ones(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// The probability this stream encodes: density of 1s.
    pub fn value(&self) -> f64 {
        if self.len == 0 {
            return 0.0;
        }
        self.count_ones() as f64 / self.len as f64
    }

    /// Iterator over bits.
    pub fn iter(&self) -> impl Iterator<Item = bool> + '_ {
        (0..self.len).map(move |i| self.get(i))
    }

    fn check_len(&self, other: &Bitstream) -> Result<()> {
        if self.len != other.len {
            return Err(Error::LengthMismatch { lhs: self.len, rhs: other.len });
        }
        Ok(())
    }

    /// Bitwise AND — the uncorrelated SC multiplier.
    pub fn and(&self, other: &Bitstream) -> Result<Bitstream> {
        self.check_len(other)?;
        let words = self.words.iter().zip(&other.words).map(|(a, b)| a & b).collect();
        Ok(Bitstream { words, len: self.len })
    }

    /// Bitwise OR.
    pub fn or(&self, other: &Bitstream) -> Result<Bitstream> {
        self.check_len(other)?;
        let words = self.words.iter().zip(&other.words).map(|(a, b)| a | b).collect();
        Ok(Bitstream { words, len: self.len })
    }

    /// Bitwise XOR.
    pub fn xor(&self, other: &Bitstream) -> Result<Bitstream> {
        self.check_len(other)?;
        let words = self.words.iter().zip(&other.words).map(|(a, b)| a ^ b).collect();
        Ok(Bitstream { words, len: self.len })
    }

    /// Bitwise NOT — SC complement `1 − p`.
    pub fn not(&self) -> Bitstream {
        let mut s = Bitstream {
            words: self.words.iter().map(|w| !w).collect(),
            len: self.len,
        };
        s.mask_tail();
        s
    }

    /// MUX select: `out = (sel & b) | (!sel & a)` — the SC weighted adder
    /// when `sel` is uncorrelated with both inputs.
    pub fn mux(&self, other: &Bitstream, sel: &Bitstream) -> Result<Bitstream> {
        self.check_len(other)?;
        self.check_len(sel)?;
        let words = self
            .words
            .iter()
            .zip(&other.words)
            .zip(&sel.words)
            .map(|((a, b), s)| (s & b) | (!s & a))
            .collect();
        Ok(Bitstream { words, len: self.len })
    }

    /// In-place AND into `self` (allocation-free hot path).
    pub fn and_assign(&mut self, other: &Bitstream) -> Result<()> {
        self.check_len(other)?;
        for (a, b) in self.words.iter_mut().zip(&other.words) {
            *a &= b;
        }
        Ok(())
    }

    /// In-place MUX into `self` (`self = sel ? b : self`).
    pub fn mux_assign(&mut self, b: &Bitstream, sel: &Bitstream) -> Result<()> {
        self.check_len(b)?;
        self.check_len(sel)?;
        for ((a, b), s) in self.words.iter_mut().zip(&b.words).zip(&sel.words) {
            *a = (s & b) | (!s & *a);
        }
        Ok(())
    }
}

/// Mask keeping the valid bits of the **last** packed word of an
/// `n_bits` stream (all-ones when `n_bits` is a multiple of 64).
///
/// The single source of the tail-bit convention — shared by
/// [`Bitstream::mask_tail`], the SNE encode hot path, and the batched
/// decision engine, so the packing invariant lives in one place.
#[inline]
pub(crate) fn tail_word_mask(n_bits: usize) -> u64 {
    let tail = n_bits % 64;
    if tail == 0 {
        u64::MAX
    } else {
        (1u64 << tail) - 1
    }
}

/// Reusable buffer pool so the coordinator's steady state allocates
/// nothing per decision.
#[derive(Debug, Default)]
pub struct BitstreamPool {
    free: Vec<Bitstream>,
    len: usize,
}

impl BitstreamPool {
    /// Pool handing out streams of `len` bits.
    pub fn new(len: usize) -> Self {
        Self { free: Vec::new(), len }
    }

    /// Bit length of pooled streams.
    pub fn stream_len(&self) -> usize {
        self.len
    }

    /// Number of pooled (idle) buffers.
    pub fn idle(&self) -> usize {
        self.free.len()
    }

    /// Take a zeroed stream from the pool (or allocate).
    pub fn take(&mut self) -> Bitstream {
        match self.free.pop() {
            Some(mut s) => {
                for w in s.words_mut() {
                    *w = 0;
                }
                s
            }
            None => Bitstream::zeros(self.len),
        }
    }

    /// Return a stream to the pool. Streams of the wrong length are dropped.
    pub fn put(&mut self, s: Bitstream) {
        if s.len() == self.len {
            self.free.push(s);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_ones_and_value() {
        assert_eq!(Bitstream::zeros(100).value(), 0.0);
        assert_eq!(Bitstream::ones(100).value(), 1.0);
        assert_eq!(Bitstream::ones(100).count_ones(), 100);
        // Non-multiple-of-64 lengths keep the tail clean.
        assert_eq!(Bitstream::ones(65).count_ones(), 65);
        assert_eq!(Bitstream::ones(63).not().count_ones(), 0);
    }

    #[test]
    fn get_set_roundtrip() {
        let mut s = Bitstream::zeros(130);
        s.set(0, true);
        s.set(64, true);
        s.set(129, true);
        assert!(s.get(0) && s.get(64) && s.get(129));
        assert!(!s.get(1) && !s.get(128));
        assert_eq!(s.count_ones(), 3);
        s.set(64, false);
        assert_eq!(s.count_ones(), 2);
    }

    #[test]
    fn from_bits_roundtrip() {
        let bits = vec![true, false, true, true, false, false, true];
        let s = Bitstream::from_bits(&bits);
        let back: Vec<bool> = s.iter().collect();
        assert_eq!(bits, back);
    }

    #[test]
    fn gate_ops_match_boolean_semantics() {
        let a = Bitstream::from_bits(&[true, true, false, false]);
        let b = Bitstream::from_bits(&[true, false, true, false]);
        assert_eq!(
            a.and(&b).unwrap(),
            Bitstream::from_bits(&[true, false, false, false])
        );
        assert_eq!(
            a.or(&b).unwrap(),
            Bitstream::from_bits(&[true, true, true, false])
        );
        assert_eq!(
            a.xor(&b).unwrap(),
            Bitstream::from_bits(&[false, true, true, false])
        );
        assert_eq!(a.not(), Bitstream::from_bits(&[false, false, true, true]));
    }

    #[test]
    fn mux_selects_b_on_high() {
        let a = Bitstream::from_bits(&[true, true, false, false]);
        let b = Bitstream::from_bits(&[false, false, true, true]);
        let sel = Bitstream::from_bits(&[false, true, false, true]);
        // sel=0 -> a, sel=1 -> b
        assert_eq!(
            a.mux(&b, &sel).unwrap(),
            Bitstream::from_bits(&[true, false, false, true])
        );
    }

    #[test]
    fn length_mismatch_is_error() {
        let a = Bitstream::zeros(10);
        let b = Bitstream::zeros(11);
        assert!(a.and(&b).is_err());
        assert!(a.mux(&a, &b).is_err());
        let mut c = a.clone();
        assert!(c.and_assign(&b).is_err());
    }

    #[test]
    fn in_place_ops_match_pure_ops() {
        let a = Bitstream::from_bits(&[true, false, true, true, false]);
        let b = Bitstream::from_bits(&[true, true, false, true, false]);
        let sel = Bitstream::from_bits(&[false, true, true, false, true]);
        let mut x = a.clone();
        x.and_assign(&b).unwrap();
        assert_eq!(x, a.and(&b).unwrap());
        let mut y = a.clone();
        y.mux_assign(&b, &sel).unwrap();
        assert_eq!(y, a.mux(&b, &sel).unwrap());
    }

    #[test]
    fn pool_reuses_buffers() {
        let mut pool = BitstreamPool::new(128);
        let mut s = pool.take();
        s.set(5, true);
        pool.put(s);
        assert_eq!(pool.idle(), 1);
        let s2 = pool.take(); // must come back zeroed
        assert_eq!(s2.count_ones(), 0);
        assert_eq!(pool.idle(), 0);
        // Wrong-length returns are dropped.
        pool.put(Bitstream::zeros(64));
        assert_eq!(pool.idle(), 0);
    }

    #[test]
    fn from_words_validates_and_masks() {
        assert!(Bitstream::from_words(vec![u64::MAX], 65).is_err());
        let s = Bitstream::from_words(vec![u64::MAX], 10).unwrap();
        assert_eq!(s.count_ones(), 10);
    }
}
