//! Stochastic-number correlation metrics — the paper's Methods section:
//! Pearson correlation `ρ` and the stochastic-computing correlation `SCC`
//! of Alaghi & Hayes, both computed from the 2×2 pair counts of two
//! streams. Used for the Fig. 3c/d and S10c/d correlation matrices.


use crate::{Error, Result};

use super::Bitstream;

/// Counts of (1,1), (1,0), (0,1), (0,0) bit pairs: `a, b, c, d` in the
/// paper's notation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PairCounts {
    /// # of positions where both streams are 1.
    pub a: u64,
    /// # of positions where x=1, y=0.
    pub b: u64,
    /// # of positions where x=0, y=1.
    pub c: u64,
    /// # of positions where both are 0.
    pub d: u64,
}

impl PairCounts {
    /// Total pairs.
    pub fn n(&self) -> u64 {
        self.a + self.b + self.c + self.d
    }
}

/// Count bit pairs between two equal-length streams (word-parallel).
pub fn pair_counts(x: &Bitstream, y: &Bitstream) -> Result<PairCounts> {
    if x.len() != y.len() {
        return Err(Error::LengthMismatch { lhs: x.len(), rhs: y.len() });
    }
    let mut a = 0u64;
    let mut b = 0u64;
    let mut c = 0u64;
    for (&wx, &wy) in x.words().iter().zip(y.words()) {
        a += (wx & wy).count_ones() as u64;
        b += (wx & !wy).count_ones() as u64;
        c += (!wx & wy).count_ones() as u64;
    }
    let n = x.len() as u64;
    let d = n - a - b - c;
    Ok(PairCounts { a, b, c, d })
}

/// Pearson correlation of two bitstreams (paper Methods, Eq. for ρ):
/// `(ad − bc) / sqrt((a+b)(a+c)(b+d)(c+d))`. Returns 0 for degenerate
/// (constant) streams.
pub fn pearson(x: &Bitstream, y: &Bitstream) -> Result<f64> {
    let pc = pair_counts(x, y)?;
    Ok(pearson_from_counts(&pc))
}

/// Pearson ρ from pre-computed pair counts.
pub fn pearson_from_counts(pc: &PairCounts) -> f64 {
    let (a, b, c, d) = (pc.a as f64, pc.b as f64, pc.c as f64, pc.d as f64);
    let denom = ((a + b) * (a + c) * (b + d) * (c + d)).sqrt();
    if denom == 0.0 {
        0.0
    } else {
        (a * d - b * c) / denom
    }
}

/// SC correlation (SCC) of Alaghi & Hayes (paper Methods):
///
/// ```text
/// SCC = (ad − bc) / (n·min(a+b, a+c) − (a+b)(a+c))        if ad ≥ bc
///     = (ad − bc) / ((a+b)(a+c) − n·max(a − d, 0))         otherwise
/// ```
///
/// `+1` means maximal positive correlation (overlapping streams), `−1`
/// maximal negative, `0` independence. Degenerate denominators yield 0.
pub fn scc(x: &Bitstream, y: &Bitstream) -> Result<f64> {
    let pc = pair_counts(x, y)?;
    Ok(scc_from_counts(&pc))
}

/// SCC from pre-computed pair counts.
pub fn scc_from_counts(pc: &PairCounts) -> f64 {
    let (a, b, c, d) = (pc.a as f64, pc.b as f64, pc.c as f64, pc.d as f64);
    let n = a + b + c + d;
    let num = a * d - b * c;
    let denom = if num >= 0.0 {
        n * (a + b).min(a + c) - (a + b) * (a + c)
    } else {
        (a + b) * (a + c) - n * (a - d).max(0.0)
    };
    if denom == 0.0 {
        0.0
    } else {
        num / denom
    }
}

/// Pairwise correlation matrices over a set of named streams — the
/// Fig. 3c/d and Fig. S10c/d artefacts.
#[derive(Debug, Clone)]
pub struct CorrelationReport {
    /// Node names in matrix order.
    pub names: Vec<String>,
    /// Pearson ρ matrix (row-major).
    pub pearson: Vec<Vec<f64>>,
    /// SCC matrix (row-major).
    pub scc: Vec<Vec<f64>>,
}

impl CorrelationReport {
    /// Compute both matrices over `streams`.
    pub fn compute(names: &[&str], streams: &[&Bitstream]) -> Result<Self> {
        assert_eq!(names.len(), streams.len());
        let k = streams.len();
        let mut pm = vec![vec![0.0; k]; k];
        let mut sm = vec![vec![0.0; k]; k];
        for i in 0..k {
            for j in 0..k {
                if i == j {
                    pm[i][j] = 1.0;
                    sm[i][j] = 1.0;
                } else {
                    let pc = pair_counts(streams[i], streams[j])?;
                    pm[i][j] = pearson_from_counts(&pc);
                    sm[i][j] = scc_from_counts(&pc);
                }
            }
        }
        Ok(Self {
            names: names.iter().map(|s| s.to_string()).collect(),
            pearson: pm,
            scc: sm,
        })
    }

    /// Render as an aligned text table (used by the figure CLI).
    pub fn to_table(&self) -> String {
        let mut out = String::new();
        for (title, m) in [("Pearson ρ", &self.pearson), ("SCC", &self.scc)] {
            out.push_str(&format!("{title}:\n        "));
            for n in &self.names {
                out.push_str(&format!("{n:>8}"));
            }
            out.push('\n');
            for (i, row) in m.iter().enumerate() {
                out.push_str(&format!("{:>8}", self.names[i]));
                for v in row {
                    out.push_str(&format!("{v:>8.3}"));
                }
                out.push('\n');
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bs(bits: &[u8]) -> Bitstream {
        Bitstream::from_bits(&bits.iter().map(|&b| b == 1).collect::<Vec<_>>())
    }

    #[test]
    fn pair_counts_basic() {
        let x = bs(&[1, 1, 0, 0, 1]);
        let y = bs(&[1, 0, 1, 0, 1]);
        let pc = pair_counts(&x, &y).unwrap();
        assert_eq!(pc, PairCounts { a: 2, b: 1, c: 1, d: 1 });
        assert_eq!(pc.n(), 5);
    }

    #[test]
    fn identical_streams_have_unit_correlation() {
        let x = bs(&[1, 0, 1, 1, 0, 0, 1, 0]);
        assert!((pearson(&x, &x).unwrap() - 1.0).abs() < 1e-12);
        assert!((scc(&x, &x).unwrap() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn complementary_streams_have_negative_correlation() {
        let x = bs(&[1, 0, 1, 1, 0, 0, 1, 0]);
        let y = x.not();
        assert!((pearson(&x, &y).unwrap() + 1.0).abs() < 1e-12);
        assert!((scc(&x, &y).unwrap() + 1.0).abs() < 1e-12);
    }

    #[test]
    fn overlapping_streams_scc_is_plus_one() {
        // y ⊂ x (comonotone quantile encoding): SCC must be +1 even though
        // Pearson is < 1.
        let x = bs(&[1, 1, 1, 1, 0, 0, 0, 0]);
        let y = bs(&[1, 1, 0, 0, 0, 0, 0, 0]);
        assert!((scc(&x, &y).unwrap() - 1.0).abs() < 1e-12);
        assert!(pearson(&x, &y).unwrap() < 1.0);
    }

    #[test]
    fn degenerate_streams_give_zero() {
        let x = bs(&[1, 1, 1, 1]);
        let y = bs(&[1, 0, 1, 0]);
        assert_eq!(pearson(&x, &y).unwrap(), 0.0);
        assert_eq!(scc(&x, &y).unwrap(), 0.0);
    }

    #[test]
    fn metrics_bounded() {
        // Exhaustive over all 6-bit stream pairs: ρ, SCC ∈ [−1, 1].
        for xv in 0u8..64 {
            for yv in 0u8..64 {
                let x = bs(&(0..6).map(|i| (xv >> i) & 1).collect::<Vec<_>>());
                let y = bs(&(0..6).map(|i| (yv >> i) & 1).collect::<Vec<_>>());
                let p = pearson(&x, &y).unwrap();
                let s = scc(&x, &y).unwrap();
                assert!((-1.0..=1.0).contains(&p), "rho {p} for {xv},{yv}");
                assert!((-1.0 - 1e-9..=1.0 + 1e-9).contains(&s), "scc {s} for {xv},{yv}");
            }
        }
    }

    #[test]
    fn length_mismatch_rejected() {
        let x = Bitstream::zeros(8);
        let y = Bitstream::zeros(9);
        assert!(pair_counts(&x, &y).is_err());
    }

    #[test]
    fn report_has_unit_diagonal_and_is_symmetric_enough() {
        let x = bs(&[1, 0, 1, 1, 0, 0, 1, 0]);
        let y = bs(&[1, 1, 0, 1, 0, 1, 0, 0]);
        let r = CorrelationReport::compute(&["x", "y"], &[&x, &y]).unwrap();
        assert_eq!(r.pearson[0][0], 1.0);
        assert_eq!(r.scc[1][1], 1.0);
        assert!((r.pearson[0][1] - r.pearson[1][0]).abs() < 1e-12);
        let table = r.to_table();
        assert!(table.contains("Pearson"));
        assert!(table.contains("SCC"));
    }
}
