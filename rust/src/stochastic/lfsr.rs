//! LFSR-based stochastic number generation — the *baseline* encoder.
//!
//! The paper's introduction contrasts memristor SNEs with classic
//! linear-feedback-shift-register stochastic circuits, which need careful
//! pre-/post-processing because LFSR streams sharing one register (or with
//! related seeds) carry **improper correlations** that corrupt SC results.
//! We implement a Fibonacci LFSR encoder so the ablation benches can
//! measure exactly that failure mode (and its hardware-cost difference:
//! an n-bit LFSR + comparator per stream vs one memristor + comparator).


use crate::{Error, Result};

use super::Bitstream;

/// Maximal-length tap masks for Fibonacci LFSRs (XOR form), indexed by
/// register width. Source: standard primitive-polynomial tables.
const TAPS: &[(u32, u64)] = &[
    (8, 0b1011_1000),                  // x^8 + x^6 + x^5 + x^4 + 1
    (16, 0b1101_0000_0000_1000),       // x^16 + x^15 + x^13 + x^4 + 1
    (24, 0xE1_0000),                   // x^24 + x^23 + x^22 + x^17 + 1
    (32, 0x8020_0003),                 // x^32 + x^22 + x^2 + x + 1
];

/// A Fibonacci LFSR over `width` bits.
#[derive(Debug, Clone)]
pub struct Lfsr {
    state: u64,
    taps: u64,
    width: u32,
}

impl Lfsr {
    /// Create an LFSR of the given width (8, 16, 24 or 32) and nonzero seed.
    pub fn new(width: u32, seed: u64) -> Result<Self> {
        let taps = TAPS
            .iter()
            .find(|&&(w, _)| w == width)
            .map(|&(_, t)| t)
            .ok_or_else(|| Error::Config(format!("unsupported LFSR width {width}")))?;
        let mask = (1u64 << width) - 1;
        let state = seed & mask;
        if state == 0 {
            return Err(Error::Config("LFSR seed must be nonzero".into()));
        }
        Ok(Self { state, taps, width })
    }

    /// Advance one step and return the new state.
    pub fn step(&mut self) -> u64 {
        let fb = (self.state & self.taps).count_ones() as u64 & 1;
        self.state = ((self.state << 1) | fb) & ((1u64 << self.width) - 1);
        if self.state == 0 {
            // Unreachable for maximal-length taps, but stay safe.
            self.state = 1;
        }
        self.state
    }

    /// Current state.
    pub fn state(&self) -> u64 {
        self.state
    }

    /// Register width.
    pub fn width(&self) -> u32 {
        self.width
    }

    /// Period of a maximal-length LFSR: `2^width − 1`.
    pub fn period(&self) -> u64 {
        (1u64 << self.width) - 1
    }
}

/// Stochastic number encoder driven by an LFSR + digital comparator.
#[derive(Debug, Clone)]
pub struct LfsrEncoder {
    lfsr: Lfsr,
}

impl LfsrEncoder {
    /// Encoder with its own register.
    pub fn new(width: u32, seed: u64) -> Result<Self> {
        Ok(Self { lfsr: Lfsr::new(width, seed)? })
    }

    /// Encode `p` as `n_bits`: bit_k = (state_k < p·2^width).
    pub fn encode(&mut self, p: f64, n_bits: usize) -> Result<Bitstream> {
        Error::check_prob("p", p)?;
        let threshold = (p * (self.lfsr.period() + 1) as f64) as u64;
        let mut out = Bitstream::zeros(n_bits);
        for i in 0..n_bits {
            if self.lfsr.step() < threshold {
                out.set(i, true);
            }
        }
        Ok(out)
    }

    /// The classic shared-register pitfall: encode two probabilities from
    /// the *same* LFSR states (one comparator each). The streams are
    /// maximally correlated — exactly the "improper correlation" the paper
    /// says corrupts uncorrelated SC arithmetic.
    pub fn encode_shared(&mut self, ps: &[f64], n_bits: usize) -> Result<Vec<Bitstream>> {
        for &p in ps {
            Error::check_prob("p", p)?;
        }
        let thresholds: Vec<u64> =
            ps.iter().map(|&p| (p * (self.lfsr.period() + 1) as f64) as u64).collect();
        let mut outs: Vec<Bitstream> = ps.iter().map(|_| Bitstream::zeros(n_bits)).collect();
        for i in 0..n_bits {
            let s = self.lfsr.step();
            for (out, &t) in outs.iter_mut().zip(&thresholds) {
                if s < t {
                    out.set(i, true);
                }
            }
        }
        Ok(outs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stochastic::scc;

    #[test]
    fn lfsr_is_maximal_length() {
        let mut l = Lfsr::new(16, 0xACE1).unwrap();
        let start = l.state();
        let mut period = 0u64;
        loop {
            l.step();
            period += 1;
            if l.state() == start {
                break;
            }
            assert!(period <= l.period(), "period exceeded 2^16-1");
        }
        assert_eq!(period, 65_535);
    }

    #[test]
    fn encoder_hits_probability() {
        let mut e = LfsrEncoder::new(16, 0xBEEF).unwrap();
        for &p in &[0.25, 0.5, 0.72] {
            let s = e.encode(p, 20_000).unwrap();
            assert!((s.value() - p).abs() < 0.02, "p={p} got {}", s.value());
        }
    }

    #[test]
    fn shared_register_streams_are_improperly_correlated() {
        let mut e = LfsrEncoder::new(16, 0x1234).unwrap();
        let ss = e.encode_shared(&[0.5, 0.6], 10_000).unwrap();
        // The defect under test: SCC ≈ +1, so AND(x,y) = min, not product.
        let c = scc(&ss[0], &ss[1]).unwrap();
        assert!(c > 0.9, "shared-LFSR SCC should be ~1, got {c}");
        let and = ss[0].and(&ss[1]).unwrap();
        assert!((and.value() - 0.5).abs() < 0.03, "AND acted like min()");
        assert!((and.value() - 0.3).abs() > 0.1, "AND should NOT equal product");
    }

    #[test]
    fn distinct_seeds_reduce_but_dont_eliminate_structure() {
        // Two LFSRs with different seeds: same sequence, shifted phase.
        let mut e1 = LfsrEncoder::new(16, 0x0001).unwrap();
        let mut e2 = LfsrEncoder::new(16, 0x8011).unwrap();
        let s1 = e1.encode(0.5, 20_000).unwrap();
        let s2 = e2.encode(0.5, 20_000).unwrap();
        let c = scc(&s1, &s2).unwrap();
        // Phase-shifted m-sequences decorrelate fairly well…
        assert!(c.abs() < 0.2, "scc {c}");
    }

    #[test]
    fn invalid_construction_rejected() {
        assert!(Lfsr::new(12, 1).is_err());
        assert!(Lfsr::new(16, 0).is_err());
        assert!(LfsrEncoder::new(16, 1).unwrap().encode(1.5, 10).is_err());
    }
}
