//! Stochastic-number machinery: packed bitstreams, memristor-backed
//! stochastic number encoders (SNEs), correlation metrics, and the LFSR
//! baseline encoder the paper's introduction argues against.
//!
//! A *stochastic number* encodes a probability `p` as a stream of `n`
//! Bernoulli bits whose density of 1s is `p` (unipolar format). Boolean
//! gates over such streams compute arithmetic on the probabilities — which
//! gate computes what depends on the *correlation* between the operand
//! streams (Table S1), so correlation control is a first-class concern:
//! one SNE produces correlated streams, parallel SNEs produce
//! uncorrelated streams.

mod bitstream;
mod correlation;
mod lfsr;
mod sne;

pub use bitstream::{Bitstream, BitstreamPool};
pub(crate) use bitstream::tail_word_mask;
pub use correlation::{pair_counts, pearson, scc, CorrelationReport, PairCounts};
pub use lfsr::{Lfsr, LfsrEncoder};
pub use sne::{GroupChunkEncoder, GroupShardSession, Sne, SneBank, SneConfig};
