//! Stochastic number encoders (SNEs) — Fig. 2a/S5.
//!
//! An SNE is a volatile memristor plus a comparator chain. Pulsing the
//! memristor at `V_in` yields stochastic switching; the comparator
//! binarises the output against `V_ref`. Two regimes:
//!
//! * **Uncorrelated** — parallel SNEs (one memristor each) produce
//!   independent streams; the encoded probability is set by `V_in`
//!   (Fig. 2b: `P_unc = σ(3.56·(V_in − 2.24))`).
//! * **Correlated** — one SNE feeds several comparators with different
//!   `V_ref`; all streams binarise the *same* analog sample, so they are
//!   maximally positively correlated (SCC → +1); the probability is set by
//!   `V_ref` (Fig. 2c: `P_corr = 1 − σ(11.5·(V_ref − 0.57))`).
//!
//! The paper's operators "maximise the sharing of the SNEs"; [`SneBank`]
//! is that shared pool, with wear rotation and an energy/time ledger.


use crate::device::{DeviceParams, EnergyTimeLedger, Memristor, WearPolicy};
use crate::util::Rng;
use crate::{Error, Result};

use super::{tail_word_mask, Bitstream};

/// SNE/bank configuration.
#[derive(Debug, Clone)]
pub struct SneConfig {
    /// Bits per stochastic number. Paper demos use 100.
    pub n_bits: usize,
    /// Device parameter set.
    pub params: DeviceParams,
    /// Number of physical SNEs in the bank.
    pub n_snes: usize,
    /// What to do when a device exceeds its endurance budget.
    pub wear_policy: WearPolicy,
}

impl Default for SneConfig {
    fn default() -> Self {
        Self {
            n_bits: 100,
            params: DeviceParams::default(),
            n_snes: 16,
            wear_policy: WearPolicy::Rotate,
        }
    }
}

impl SneConfig {
    /// Validate the configuration.
    pub fn validate(&self) -> Result<()> {
        if self.n_bits == 0 {
            return Err(Error::Config("n_bits must be > 0".into()));
        }
        if self.n_snes == 0 {
            return Err(Error::Config("n_snes must be > 0".into()));
        }
        self.params.validate()
    }
}

/// One stochastic number encoder.
#[derive(Debug, Clone)]
pub struct Sne {
    device: Memristor,
}

impl Sne {
    /// Wrap a memristor as an SNE.
    pub fn new(device: Memristor) -> Self {
        Self { device }
    }

    /// The underlying device.
    pub fn device(&self) -> &Memristor {
        &self.device
    }

    /// Pulse amplitude that encodes probability `p` (uncorrelated mode).
    pub fn voltage_for(&self, p: f64) -> f64 {
        self.device.voltage_for_probability(p)
    }

    /// Comparator reference that encodes probability `p` (correlated mode):
    /// `V_ref` such that `P(analog_out > V_ref) = p` given the device
    /// switched (inverse of the Fig. 2c curve).
    pub fn ref_for(&self, p: f64) -> f64 {
        let d = self.device.params();
        let q = p.clamp(1e-9, 1.0 - 1e-9);
        d.analog_out_center + d.analog_out_scale * ((1.0 - q) / q).ln()
    }

    /// Encode `p` as an `n_bits` uncorrelated stream by pulsing the device.
    ///
    /// With `drift_coupling == 0` (the default, ideal-device setting) the
    /// per-pulse switching is i.i.d. Bernoulli with exactly the Fig. 2b
    /// probability, so we take a vectorised fast path; otherwise we walk
    /// the full pulse-by-pulse device model.
    pub fn encode(
        &mut self,
        p: f64,
        n_bits: usize,
        ledger: &mut EnergyTimeLedger,
        rng: &mut Rng,
    ) -> Result<Bitstream> {
        let mut out = Bitstream::zeros(n_bits);
        self.encode_into_words(p, n_bits, out.words_mut(), ledger, rng)?;
        Ok(out)
    }

    /// Encode `p` directly into a caller-provided packed word buffer
    /// (`words.len()` must be `n_bits.div_ceil(64)`). This is the
    /// allocation-free hot path under [`crate::bayes`]'s batched engine;
    /// the RNG consumption, ledger updates, and produced bits are
    /// **identical** to [`Self::encode`] (which delegates here), so the
    /// batched and single-decision paths stay bit-for-bit equivalent.
    pub(crate) fn encode_into_words(
        &mut self,
        p: f64,
        n_bits: usize,
        words: &mut [u64],
        ledger: &mut EnergyTimeLedger,
        rng: &mut Rng,
    ) -> Result<()> {
        Error::check_prob("p", p)?;
        debug_assert_eq!(words.len(), n_bits.div_ceil(64));
        let energy = self.device.params().switch_energy_nj;
        words.iter_mut().for_each(|w| *w = 0);
        if self.device.params().drift_coupling == 0.0 {
            // Fast path: per-pulse switching is i.i.d. Bernoulli with the
            // Fig. 2b probability, so generate whole 64-bit words by the
            // binary-expansion construction: with prob quantised to
            // q/2^16, z starts at 0 and folds one random word per bit of
            // q (LSB→MSB): z = bit ? z|r : z&!r, giving P(z_k=1) = q/2^16
            // with ≤16 RNG draws per word instead of 64 (§Perf L3-2).
            //
            // The SNE programs `V_in = voltage_for(p)` and the device then
            // switches with `switch_probability(V_in)`; the calibration
            // inverts exactly (σ ∘ logit, same per-device centre), so the
            // Bernoulli rate is `p` itself modulo the clamp — no need to
            // pay the ln/exp round-trip per stream on this hot path.
            let prob = p.clamp(1e-9, 1.0 - 1e-9);
            let q = (prob * 65536.0).round() as u32; // 2^-16 resolution
            if q >= 65536 {
                for w in words.iter_mut() {
                    *w = u64::MAX;
                }
            } else if q > 0 {
                let lo = q.trailing_zeros(); // z stays 0 below the lowest set bit
                for w in words.iter_mut() {
                    let mut z = 0u64;
                    for i in lo..16 {
                        let r = rng.next_u64();
                        z = if (q >> i) & 1 == 1 { z | r } else { z & !r };
                    }
                    *w = z;
                }
            }
            if let Some(last) = words.last_mut() {
                *last &= tail_word_mask(n_bits);
            }
            let switches: usize = words.iter().map(|w| w.count_ones() as usize).sum();
            self.device.record_switches(switches as u64);
            ledger.pulses += n_bits as u64;
            ledger.switch_events += switches as u64;
            ledger.energy_nj += switches as f64 * energy;
        } else {
            let v_in = self.voltage_for(p);
            for i in 0..n_bits {
                let ev = self.device.pulse(v_in, rng);
                ledger.record_pulse(ev.switched, ev.energy_nj);
                if ev.switched {
                    words[i / 64] |= 1 << (i % 64);
                }
            }
        }
        Ok(())
    }

    /// Encode several probabilities as **maximally correlated** streams
    /// from this single SNE: every bit slot shares one analog sample,
    /// binarised against per-stream references.
    pub fn encode_correlated(
        &mut self,
        probs: &[f64],
        n_bits: usize,
        ledger: &mut EnergyTimeLedger,
        rng: &mut Rng,
    ) -> Result<Vec<Bitstream>> {
        for &p in probs {
            Error::check_prob("p", p)?;
        }
        let mut outs: Vec<Bitstream> = probs.iter().map(|_| Bitstream::zeros(n_bits)).collect();
        if self.device.params().drift_coupling == 0.0 {
            // Fast path (§Perf L3-3): driven hard, the device switches
            // every slot and the analog node is an i.i.d. logistic
            // sample; `bit_i = analog > ref_for(p_i)` is comonotone in
            // the sample's CDF value u, i.e. exactly `bit_i = u < p_i`
            // with ONE shared uniform per slot. Same joint law as the
            // pulse-by-pulse model, ~25× cheaper.
            let thresholds: Vec<u64> =
                probs.iter().map(|&p| (p * u64::MAX as f64) as u64).collect();
            // Word-at-a-time: build all streams' words in registers to
            // avoid per-bit bounds checks.
            let n_words = n_bits.div_ceil(64);
            let mut acc = vec![0u64; thresholds.len()];
            for w in 0..n_words {
                acc.iter_mut().for_each(|a| *a = 0);
                for k in 0..64 {
                    let u = rng.next_u64();
                    for (a, &thr) in acc.iter_mut().zip(&thresholds) {
                        *a |= ((u <= thr) as u64) << k;
                    }
                }
                for (out, &a) in outs.iter_mut().zip(&acc) {
                    out.words_mut()[w] = a;
                }
            }
            for out in outs.iter_mut() {
                out.mask_tail();
            }
            let energy = self.device.params().switch_energy_nj;
            self.device.record_switches(n_bits as u64);
            ledger.pulses += n_bits as u64;
            ledger.switch_events += n_bits as u64;
            ledger.energy_nj += n_bits as f64 * energy;
        } else {
            let refs: Vec<f64> = probs.iter().map(|&p| self.ref_for(p)).collect();
            // Drive hard so the device switches every slot: the encoded
            // probability lives entirely in the comparator references.
            let v_drive = self.voltage_for(1.0 - 1e-9);
            for i in 0..n_bits {
                let ev = self.device.pulse(v_drive, rng);
                ledger.record_pulse(ev.switched, ev.energy_nj);
                if ev.switched {
                    for (out, &r) in outs.iter_mut().zip(&refs) {
                        if ev.analog_out > r {
                            out.set(i, true);
                        }
                    }
                }
            }
        }
        Ok(outs)
    }

    /// Is the device worn out?
    pub fn is_worn(&self) -> bool {
        self.device.is_worn()
    }
}

/// A pool of SNEs with an owned RNG, wear rotation and a shared ledger.
///
/// Streams drawn from *different* `encode_*` calls use distinct SNEs in
/// round-robin, mirroring the paper's parallel-SNE uncorrelated wiring.
pub struct SneBank {
    config: SneConfig,
    snes: Vec<Sne>,
    spares: Vec<Sne>,
    next: usize,
    ledger: EnergyTimeLedger,
    rng: Rng,
}

impl SneBank {
    /// Build a bank from a config and seed. Fabricates `2×n_snes`
    /// devices: half active, half spares for wear rotation.
    pub fn new(config: SneConfig, seed: u64) -> Result<Self> {
        config.validate()?;
        let mut rng = Rng::seeded(seed);
        let mk = |rng: &mut Rng| Sne::new(Memristor::sampled(config.params.clone(), rng));
        let snes = (0..config.n_snes).map(|_| mk(&mut rng)).collect();
        let spares = (0..config.n_snes).map(|_| mk(&mut rng)).collect();
        Ok(Self { config, snes, spares, next: 0, ledger: EnergyTimeLedger::new(), rng })
    }

    /// Default-config bank from a seed.
    pub fn seeded(seed: u64) -> Self {
        Self::new(SneConfig::default(), seed).expect("default config is valid")
    }

    /// Bank configuration.
    pub fn config(&self) -> &SneConfig {
        &self.config
    }

    /// The shared energy/time ledger.
    pub fn ledger(&self) -> &EnergyTimeLedger {
        &self.ledger
    }

    /// Stream length this bank encodes.
    pub fn n_bits(&self) -> usize {
        self.config.n_bits
    }

    /// Count of active (non-spare) SNEs.
    pub fn n_snes(&self) -> usize {
        self.snes.len()
    }

    /// Remaining spares.
    pub fn n_spares(&self) -> usize {
        self.spares.len()
    }

    fn next_sne(&mut self) -> Result<usize> {
        let idx = self.next % self.snes.len();
        self.next = self.next.wrapping_add(1);
        if self.snes[idx].is_worn() {
            match self.config.wear_policy {
                WearPolicy::Ignore => {}
                WearPolicy::Rotate => {
                    if let Some(spare) = self.spares.pop() {
                        self.snes[idx] = spare;
                    } else {
                        let dev = self.snes[idx].device();
                        return Err(Error::DeviceWorn { row: 0, col: idx, cycles: dev.cycles() });
                    }
                }
                WearPolicy::Fail => {
                    let dev = self.snes[idx].device();
                    return Err(Error::DeviceWorn { row: 0, col: idx, cycles: dev.cycles() });
                }
            }
        }
        Ok(idx)
    }

    /// Encode `p` on the next SNE (uncorrelated w.r.t. other calls).
    pub fn encode(&mut self, p: f64) -> Result<Bitstream> {
        let n_bits = self.config.n_bits;
        let idx = self.next_sne()?;
        let Self { snes, ledger, rng, .. } = self;
        snes[idx].encode(p, n_bits, ledger, rng)
    }

    /// Encode `p` with an explicit bit length.
    pub fn encode_with_len(&mut self, p: f64, n_bits: usize) -> Result<Bitstream> {
        let idx = self.next_sne()?;
        let Self { snes, ledger, rng, .. } = self;
        snes[idx].encode(p, n_bits, ledger, rng)
    }

    /// Encode a group of mutually **uncorrelated** streams (parallel SNEs).
    pub fn encode_group(&mut self, probs: &[f64]) -> Result<Vec<Bitstream>> {
        probs.iter().map(|&p| self.encode(p)).collect()
    }

    /// Encode a group of mutually uncorrelated streams into one packed
    /// word buffer — the grouped, allocation-free entry the batched
    /// decision engine uses ([`crate::bayes::BatchedInference`] /
    /// [`crate::bayes::BatchedFusion`]).
    ///
    /// Stream `j` occupies `out[j*W .. (j+1)*W]` with
    /// `W = n_bits.div_ceil(64)`; `out.len()` must be `probs.len() * W`.
    /// SNEs are drawn through the same round-robin and the RNG is
    /// consumed in the same order as repeated [`Self::encode`] calls, so
    /// the packed bits are bit-identical to the single-call path.
    pub fn encode_group_into(&mut self, probs: &[f64], out: &mut [u64]) -> Result<()> {
        let n_bits = self.config.n_bits;
        let w = n_bits.div_ceil(64);
        if out.len() != probs.len() * w {
            return Err(Error::LengthMismatch {
                lhs: out.len() * 64,
                rhs: probs.len() * w * 64,
            });
        }
        for (j, &p) in probs.iter().enumerate() {
            let idx = self.next_sne()?;
            let Self { snes, ledger, rng, .. } = self;
            snes[idx].encode_into_words(p, n_bits, &mut out[j * w..(j + 1) * w], ledger, rng)?;
        }
        Ok(())
    }

    /// Encode a group of maximally **correlated** streams (one shared SNE).
    pub fn encode_correlated(&mut self, probs: &[f64]) -> Result<Vec<Bitstream>> {
        let n_bits = self.config.n_bits;
        let idx = self.next_sne()?;
        let Self { snes, ledger, rng, .. } = self;
        snes[idx].encode_correlated(probs, n_bits, ledger, rng)
    }

    /// Mark one complete decision on the ledger (advances the virtual
    /// hardware clock by one stream time — all SNEs pulse in parallel).
    pub fn finish_decision(&mut self) {
        self.ledger.record_decision(self.config.n_bits);
    }

    /// Direct access to the RNG (used by gates needing auxiliary select
    /// streams and by tests).
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stochastic::{pearson, scc};

    #[test]
    fn encode_hits_target_probability() {
        let mut bank = SneBank::new(
            SneConfig { n_bits: 20_000, ..Default::default() },
            7,
        )
        .unwrap();
        for &p in &[0.05, 0.3, 0.57, 0.72, 0.95] {
            let s = bank.encode(p).unwrap();
            assert!((s.value() - p).abs() < 0.015, "p={p} got {}", s.value());
        }
    }

    #[test]
    fn parallel_streams_are_uncorrelated() {
        let mut bank =
            SneBank::new(SneConfig { n_bits: 20_000, ..Default::default() }, 8).unwrap();
        let g = bank.encode_group(&[0.5, 0.5]).unwrap();
        let rho = pearson(&g[0], &g[1]).unwrap();
        assert!(rho.abs() < 0.03, "pearson {rho}");
        let s = scc(&g[0], &g[1]).unwrap();
        assert!(s.abs() < 0.05, "scc {s}");
    }

    #[test]
    fn shared_sne_streams_are_maximally_correlated() {
        let mut bank =
            SneBank::new(SneConfig { n_bits: 20_000, ..Default::default() }, 9).unwrap();
        let g = bank.encode_correlated(&[0.3, 0.7]).unwrap();
        assert!((g[0].value() - 0.3).abs() < 0.02);
        assert!((g[1].value() - 0.7).abs() < 0.02);
        // Comonotone: the 0.3-stream is a subset of the 0.7-stream.
        let s = scc(&g[0], &g[1]).unwrap();
        assert!(s > 0.95, "scc {s}");
        let and = g[0].and(&g[1]).unwrap();
        assert!((and.value() - 0.3).abs() < 0.02, "min() law broken");
    }

    #[test]
    fn correlated_refs_invert_fig2c() {
        let bank = SneBank::seeded(1);
        let sne = &bank.snes[0];
        for &p in &[0.1, 0.5, 0.9] {
            let vref = sne.ref_for(p);
            // Fig. 2c: P = 1 − σ(11.5 (V_ref − 0.57)) (nominal device).
            let d2d = sne.device().vth_mu() - 2.08; // ref_for is per-device
            let _ = d2d;
            let p_back = 1.0 - 1.0 / (1.0 + (-(vref - 0.57) / (1.0 / 11.5)).exp());
            assert!((p_back - p).abs() < 1e-9, "p={p} back={p_back}");
        }
    }

    #[test]
    fn wear_rotation_swaps_in_spares() {
        let params = DeviceParams { endurance_cycles: 50, ..Default::default() };
        let cfg = SneConfig { n_bits: 100, n_snes: 1, params, ..Default::default() };
        let mut bank = SneBank::new(cfg, 3).unwrap();
        assert_eq!(bank.n_spares(), 1);
        // Each 100-bit encode at p=0.99 burns ~99 cycles > the 50 budget.
        bank.encode(0.99).unwrap();
        bank.encode(0.99).unwrap(); // triggers rotation onto the spare
        assert_eq!(bank.n_spares(), 0);
        // The spare is now worn too and nothing is left -> error.
        let err = bank.encode(0.99).unwrap_err();
        assert!(matches!(err, Error::DeviceWorn { .. }));
    }

    #[test]
    fn wear_fail_policy_errors_immediately() {
        let params = DeviceParams { endurance_cycles: 10, ..Default::default() };
        let cfg = SneConfig {
            n_bits: 100,
            n_snes: 1,
            params,
            wear_policy: WearPolicy::Fail,
        };
        let mut bank = SneBank::new(cfg, 4).unwrap();
        bank.encode(0.99).unwrap();
        assert!(bank.encode(0.99).is_err());
    }

    #[test]
    fn ledger_tracks_energy_and_time() {
        let mut bank = SneBank::seeded(5);
        let s = bank.encode(0.5).unwrap();
        bank.finish_decision();
        let l = bank.ledger();
        assert_eq!(l.pulses, 100);
        assert_eq!(l.switch_events as usize, s.count_ones());
        assert!((l.clock.elapsed_ms() - 0.4).abs() < 1e-12);
        assert!((l.energy_nj - 0.16 * s.count_ones() as f64).abs() < 1e-9);
    }

    #[test]
    fn encode_group_into_matches_single_calls() {
        let mut a = SneBank::seeded(77);
        let mut b = SneBank::seeded(77);
        let probs = [0.3, 0.57, 0.72];
        let singles: Vec<Bitstream> = probs.iter().map(|&p| a.encode(p).unwrap()).collect();
        let w = b.n_bits().div_ceil(64);
        let mut packed = vec![0u64; probs.len() * w];
        b.encode_group_into(&probs, &mut packed).unwrap();
        for (j, s) in singles.iter().enumerate() {
            assert_eq!(&packed[j * w..(j + 1) * w], s.words(), "stream {j} diverged");
        }
        // Same ledger accounting on both paths.
        assert_eq!(a.ledger().pulses, b.ledger().pulses);
        assert_eq!(a.ledger().switch_events, b.ledger().switch_events);
        // Wrong buffer size is rejected.
        let mut tiny = [0u64; 1];
        assert!(b.encode_group_into(&probs, &mut tiny).is_err());
    }

    #[test]
    fn invalid_probability_rejected() {
        let mut bank = SneBank::seeded(6);
        assert!(bank.encode(1.2).is_err());
        assert!(bank.encode(-0.1).is_err());
        assert!(bank.encode_correlated(&[0.5, 1.5]).is_err());
    }

    #[test]
    fn config_validation() {
        assert!(SneConfig { n_bits: 0, ..Default::default() }.validate().is_err());
        assert!(SneConfig { n_snes: 0, ..Default::default() }.validate().is_err());
        assert!(SneConfig::default().validate().is_ok());
    }
}
