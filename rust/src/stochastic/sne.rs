//! Stochastic number encoders (SNEs) — Fig. 2a/S5.
//!
//! An SNE is a volatile memristor plus a comparator chain. Pulsing the
//! memristor at `V_in` yields stochastic switching; the comparator
//! binarises the output against `V_ref`. Two regimes:
//!
//! * **Uncorrelated** — parallel SNEs (one memristor each) produce
//!   independent streams; the encoded probability is set by `V_in`
//!   (Fig. 2b: `P_unc = σ(3.56·(V_in − 2.24))`).
//! * **Correlated** — one SNE feeds several comparators with different
//!   `V_ref`; all streams binarise the *same* analog sample, so they are
//!   maximally positively correlated (SCC → +1); the probability is set by
//!   `V_ref` (Fig. 2c: `P_corr = 1 − σ(11.5·(V_ref − 0.57))`).
//!
//! The paper's operators "maximise the sharing of the SNEs"; [`SneBank`]
//! is that shared pool, with wear rotation and an energy/time ledger.


use crate::device::{DeviceParams, EnergyTimeLedger, Memristor, WearPolicy};
use crate::util::Rng;
use crate::{Error, Result};

use super::{tail_word_mask, Bitstream};

/// SNE/bank configuration.
#[derive(Debug, Clone)]
pub struct SneConfig {
    /// Bits per stochastic number. Paper demos use 100.
    pub n_bits: usize,
    /// Device parameter set.
    pub params: DeviceParams,
    /// Number of physical SNEs in the bank.
    pub n_snes: usize,
    /// What to do when a device exceeds its endurance budget.
    pub wear_policy: WearPolicy,
}

impl Default for SneConfig {
    fn default() -> Self {
        Self {
            n_bits: 100,
            params: DeviceParams::default(),
            n_snes: 16,
            wear_policy: WearPolicy::Rotate,
        }
    }
}

impl SneConfig {
    /// Validate the configuration.
    pub fn validate(&self) -> Result<()> {
        if self.n_bits == 0 {
            return Err(Error::Config("n_bits must be > 0".into()));
        }
        if self.n_snes == 0 {
            return Err(Error::Config("n_snes must be > 0".into()));
        }
        self.params.validate()
    }
}

/// One stochastic number encoder.
#[derive(Debug, Clone)]
pub struct Sne {
    device: Memristor,
}

impl Sne {
    /// Wrap a memristor as an SNE.
    pub fn new(device: Memristor) -> Self {
        Self { device }
    }

    /// The underlying device.
    pub fn device(&self) -> &Memristor {
        &self.device
    }

    /// Pulse amplitude that encodes probability `p` (uncorrelated mode).
    pub fn voltage_for(&self, p: f64) -> f64 {
        self.device.voltage_for_probability(p)
    }

    /// Comparator reference that encodes probability `p` (correlated mode):
    /// `V_ref` such that `P(analog_out > V_ref) = p` given the device
    /// switched (inverse of the Fig. 2c curve).
    pub fn ref_for(&self, p: f64) -> f64 {
        let d = self.device.params();
        let q = p.clamp(1e-9, 1.0 - 1e-9);
        d.analog_out_center + d.analog_out_scale * ((1.0 - q) / q).ln()
    }

    /// Encode `p` as an `n_bits` uncorrelated stream by pulsing the device.
    ///
    /// With `drift_coupling == 0` (the default, ideal-device setting) the
    /// per-pulse switching is i.i.d. Bernoulli with exactly the Fig. 2b
    /// probability, so we take a vectorised fast path; otherwise we walk
    /// the full pulse-by-pulse device model.
    pub fn encode(
        &mut self,
        p: f64,
        n_bits: usize,
        ledger: &mut EnergyTimeLedger,
        rng: &mut Rng,
    ) -> Result<Bitstream> {
        let mut out = Bitstream::zeros(n_bits);
        self.encode_into_words(p, n_bits, out.words_mut(), ledger, rng)?;
        Ok(out)
    }

    /// Encode `p` directly into a caller-provided packed word buffer
    /// (`words.len()` must be `n_bits.div_ceil(64)`). This is the
    /// allocation-free hot path under [`crate::bayes`]'s batched engine;
    /// the RNG consumption, ledger updates, and produced bits are
    /// **identical** to [`Self::encode`] (which delegates here), so the
    /// batched and single-decision paths stay bit-for-bit equivalent.
    pub(crate) fn encode_into_words(
        &mut self,
        p: f64,
        n_bits: usize,
        words: &mut [u64],
        ledger: &mut EnergyTimeLedger,
        rng: &mut Rng,
    ) -> Result<()> {
        Error::check_prob("p", p)?;
        debug_assert_eq!(words.len(), n_bits.div_ceil(64));
        let energy = self.device.params().switch_energy_nj;
        words.iter_mut().for_each(|w| *w = 0);
        if self.device.params().drift_coupling == 0.0 {
            // Fast path: per-pulse switching is i.i.d. Bernoulli with the
            // Fig. 2b probability, so generate whole 64-bit words by the
            // binary-expansion construction: with prob quantised to
            // q/2^16, z starts at 0 and folds one random word per bit of
            // q (LSB→MSB): z = bit ? z|r : z&!r, giving P(z_k=1) = q/2^16
            // with ≤16 RNG draws per word instead of 64 (§Perf L3-2).
            //
            // The SNE programs `V_in = voltage_for(p)` and the device then
            // switches with `switch_probability(V_in)`; the calibration
            // inverts exactly (σ ∘ logit, same per-device centre), so the
            // Bernoulli rate is `p` itself modulo the clamp — no need to
            // pay the ln/exp round-trip per stream on this hot path.
            let prob = p.clamp(1e-9, 1.0 - 1e-9);
            let q = (prob * 65536.0).round() as u32; // 2^-16 resolution
            if q >= 65536 {
                for w in words.iter_mut() {
                    *w = u64::MAX;
                }
            } else if q > 0 {
                let lo = q.trailing_zeros(); // z stays 0 below the lowest set bit
                for w in words.iter_mut() {
                    let mut z = 0u64;
                    for i in lo..16 {
                        let r = rng.next_u64();
                        z = if (q >> i) & 1 == 1 { z | r } else { z & !r };
                    }
                    *w = z;
                }
            }
            if let Some(last) = words.last_mut() {
                *last &= tail_word_mask(n_bits);
            }
            let switches: usize = words.iter().map(|w| w.count_ones() as usize).sum();
            self.device.record_switches(switches as u64);
            ledger.pulses += n_bits as u64;
            ledger.switch_events += switches as u64;
            ledger.energy_nj += switches as f64 * energy;
        } else {
            let v_in = self.voltage_for(p);
            for i in 0..n_bits {
                let ev = self.device.pulse(v_in, rng);
                ledger.record_pulse(ev.switched, ev.energy_nj);
                if ev.switched {
                    words[i / 64] |= 1 << (i % 64);
                }
            }
        }
        Ok(())
    }

    /// Encode several probabilities as **maximally correlated** streams
    /// from this single SNE: every bit slot shares one analog sample,
    /// binarised against per-stream references.
    pub fn encode_correlated(
        &mut self,
        probs: &[f64],
        n_bits: usize,
        ledger: &mut EnergyTimeLedger,
        rng: &mut Rng,
    ) -> Result<Vec<Bitstream>> {
        for &p in probs {
            Error::check_prob("p", p)?;
        }
        let mut outs: Vec<Bitstream> = probs.iter().map(|_| Bitstream::zeros(n_bits)).collect();
        if self.device.params().drift_coupling == 0.0 {
            // Fast path (§Perf L3-3): driven hard, the device switches
            // every slot and the analog node is an i.i.d. logistic
            // sample; `bit_i = analog > ref_for(p_i)` is comonotone in
            // the sample's CDF value u, i.e. exactly `bit_i = u < p_i`
            // with ONE shared uniform per slot. Same joint law as the
            // pulse-by-pulse model, ~25× cheaper.
            let thresholds: Vec<u64> =
                probs.iter().map(|&p| (p * u64::MAX as f64) as u64).collect();
            // Word-at-a-time: build all streams' words in registers to
            // avoid per-bit bounds checks.
            let n_words = n_bits.div_ceil(64);
            let mut acc = vec![0u64; thresholds.len()];
            for w in 0..n_words {
                acc.iter_mut().for_each(|a| *a = 0);
                for k in 0..64 {
                    let u = rng.next_u64();
                    for (a, &thr) in acc.iter_mut().zip(&thresholds) {
                        *a |= ((u <= thr) as u64) << k;
                    }
                }
                for (out, &a) in outs.iter_mut().zip(&acc) {
                    out.words_mut()[w] = a;
                }
            }
            for out in outs.iter_mut() {
                out.mask_tail();
            }
            let energy = self.device.params().switch_energy_nj;
            self.device.record_switches(n_bits as u64);
            ledger.pulses += n_bits as u64;
            ledger.switch_events += n_bits as u64;
            ledger.energy_nj += n_bits as f64 * energy;
        } else {
            let refs: Vec<f64> = probs.iter().map(|&p| self.ref_for(p)).collect();
            // Drive hard so the device switches every slot: the encoded
            // probability lives entirely in the comparator references.
            let v_drive = self.voltage_for(1.0 - 1e-9);
            for i in 0..n_bits {
                let ev = self.device.pulse(v_drive, rng);
                ledger.record_pulse(ev.switched, ev.energy_nj);
                if ev.switched {
                    for (out, &r) in outs.iter_mut().zip(&refs) {
                        if ev.analog_out > r {
                            out.set(i, true);
                        }
                    }
                }
            }
        }
        Ok(outs)
    }

    /// Is the device worn out?
    pub fn is_worn(&self) -> bool {
        self.device.is_worn()
    }
}

/// An in-progress chunked grouped encode, started by
/// [`SneBank::begin_group_chunks`] and advanced by
/// [`SneBank::encode_group_chunk_into`].
///
/// Dropping the encoder before exhaustion abandons the unread remainder
/// of every stream: those pulses are never issued (no wear, no ledger
/// energy), which is exactly how the anytime evaluator converts an early
/// exit into hardware savings.
#[derive(Debug)]
pub struct GroupChunkEncoder {
    source: ChunkSource,
    n_streams: usize,
    n_bits: usize,
    words_total: usize,
    /// First word this encoder emits: 0 for whole-stream encoders, a
    /// shard offset for encoders from [`SneBank::begin_group_shards`].
    start_word: usize,
    /// One past the last word this encoder emits (`words_total` unless
    /// this is an interior shard).
    end_word: usize,
    next_word: usize,
}

#[derive(Debug)]
enum ChunkSource {
    /// Ideal-device fast path: per-stream RNG cursors, pulses on demand.
    Live(Vec<StreamCursor>),
    /// Nonideal-device path (`drift_coupling != 0`): the full streams are
    /// staged at begin (the pulse-by-pulse model's RNG consumption is
    /// data-dependent, so chunk boundaries cannot reposition the RNG
    /// without pulsing).
    Staged(Vec<u64>),
}

#[derive(Debug)]
struct StreamCursor {
    rng: Rng,
    sne: usize,
    q: u32,
    lo: u32,
}

impl StreamCursor {
    /// Replay the binary-expansion construction of
    /// [`Sne::encode_into_words`] from this cursor into `dst`, applying
    /// `tail` to the final word when given; returns the switch count
    /// (set bits after masking).
    fn emit(&mut self, dst: &mut [u64], tail: Option<u64>) -> u64 {
        if self.q >= 65536 {
            dst.iter_mut().for_each(|w| *w = u64::MAX);
        } else if self.q == 0 {
            dst.iter_mut().for_each(|w| *w = 0);
        } else {
            for word in dst.iter_mut() {
                let mut z = 0u64;
                for i in self.lo..16 {
                    let r = self.rng.next_u64();
                    z = if (self.q >> i) & 1 == 1 { z | r } else { z & !r };
                }
                *word = z;
            }
        }
        if let Some(m) = tail {
            if let Some(last) = dst.last_mut() {
                *last &= m;
            }
        }
        dst.iter().map(|w| w.count_ones() as u64).sum()
    }
}

/// Fast-path quantisation shared by every cursor-based encoder: `p`
/// rounds to `q / 2^16`, and `lo` is the lowest set bit of `q` (16 when
/// the stream needs no RNG draws at all — constant 0 or 1), so a packed
/// word costs exactly `16 − lo` draws. This fixed per-word draw count is
/// what lets a cursor be repositioned at an arbitrary word offset.
fn quantize(p: f64) -> (u32, u32) {
    let prob = p.clamp(1e-9, 1.0 - 1e-9);
    let q = (prob * 65536.0).round() as u32;
    let lo = if q == 0 || q >= 65536 { 16 } else { q.trailing_zeros() };
    (q, lo)
}

impl GroupChunkEncoder {
    /// Total bits per stream at exhaustion (the bank's configured length).
    pub fn bits_total(&self) -> usize {
        self.n_bits
    }

    /// Bits emitted per stream so far (by *this* encoder — a shard
    /// encoder counts only its own span).
    pub fn bits_done(&self) -> usize {
        (self.next_word * 64).min(self.n_bits) - (self.start_word * 64).min(self.n_bits)
    }

    /// Have all of this encoder's words been emitted?
    pub fn is_done(&self) -> bool {
        self.next_word >= self.end_word
    }

    /// Bits whose device pulses have actually been issued so far: equal
    /// to [`Self::bits_done`] on the ideal-device path, but the **full**
    /// stream length on the staged nonideal path — every pulse was
    /// walked at begin, so energy/wear (and the hardware clock the
    /// caller records) cover the whole stream there regardless of how
    /// early the readout stopped.
    pub fn bits_pulsed(&self) -> usize {
        match self.source {
            ChunkSource::Staged(_) => self.n_bits,
            ChunkSource::Live(_) => self.bits_done(),
        }
    }

    /// Number of streams in the group.
    pub fn n_streams(&self) -> usize {
        self.n_streams
    }

    /// Bank-free chunk encode for shard workers
    /// ([`SneBank::begin_group_shards`]): emits the next chunk exactly
    /// like [`SneBank::encode_group_chunk_into`] — stream `j`'s words at
    /// `out[j*cw ..]`, `cw = out.len() / n_streams` — but records
    /// nothing; per-stream switch counts accumulate into `switches` for
    /// the owner to settle via [`SneBank::finish_group_shards`] once the
    /// shards join. Only Live (ideal-device) encoders support this;
    /// staged encoders are served through the bank.
    pub(crate) fn encode_chunk_detached(
        &mut self,
        out: &mut [u64],
        switches: &mut [u64],
    ) -> usize {
        if self.n_streams == 0 || self.is_done() {
            return 0;
        }
        debug_assert_eq!(out.len() % self.n_streams, 0);
        debug_assert_eq!(switches.len(), self.n_streams);
        let cw = out.len() / self.n_streams;
        let words = cw.min(self.end_word - self.next_word);
        let is_tail = self.next_word + words == self.words_total;
        let tail = is_tail.then(|| tail_word_mask(self.n_bits));
        let ChunkSource::Live(streams) = &mut self.source else {
            return 0;
        };
        for (j, cur) in streams.iter_mut().enumerate() {
            switches[j] += cur.emit(&mut out[j * cw..j * cw + words], tail);
        }
        self.next_word += words;
        words
    }
}

/// An in-flight sharded grouped encode from
/// [`SneBank::begin_group_shards`]: one positioned [`GroupChunkEncoder`]
/// per shard plus the per-stream device assignments the owner feeds back
/// to [`SneBank::finish_group_shards`] once the shards join.
#[derive(Debug)]
pub struct GroupShardSession {
    shards: Vec<GroupChunkEncoder>,
    snes: Vec<usize>,
}

impl GroupShardSession {
    /// Number of shards.
    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Split into the per-shard encoders and the per-stream SNE indices.
    pub fn into_parts(self) -> (Vec<GroupChunkEncoder>, Vec<usize>) {
        (self.shards, self.snes)
    }
}

/// A pool of SNEs with an owned RNG, wear rotation and a shared ledger.
///
/// Streams drawn from *different* `encode_*` calls use distinct SNEs in
/// round-robin, mirroring the paper's parallel-SNE uncorrelated wiring.
pub struct SneBank {
    config: SneConfig,
    snes: Vec<Sne>,
    spares: Vec<Sne>,
    next: usize,
    ledger: EnergyTimeLedger,
    rng: Rng,
}

impl SneBank {
    /// Build a bank from a config and seed. Fabricates `2×n_snes`
    /// devices: half active, half spares for wear rotation.
    pub fn new(config: SneConfig, seed: u64) -> Result<Self> {
        config.validate()?;
        let mut rng = Rng::seeded(seed);
        let mk = |rng: &mut Rng| Sne::new(Memristor::sampled(config.params.clone(), rng));
        let snes = (0..config.n_snes).map(|_| mk(&mut rng)).collect();
        let spares = (0..config.n_snes).map(|_| mk(&mut rng)).collect();
        Ok(Self { config, snes, spares, next: 0, ledger: EnergyTimeLedger::new(), rng })
    }

    /// Default-config bank from a seed.
    pub fn seeded(seed: u64) -> Self {
        Self::new(SneConfig::default(), seed).expect("default config is valid")
    }

    /// Bank configuration.
    pub fn config(&self) -> &SneConfig {
        &self.config
    }

    /// The shared energy/time ledger.
    pub fn ledger(&self) -> &EnergyTimeLedger {
        &self.ledger
    }

    /// Stream length this bank encodes.
    pub fn n_bits(&self) -> usize {
        self.config.n_bits
    }

    /// Count of active (non-spare) SNEs.
    pub fn n_snes(&self) -> usize {
        self.snes.len()
    }

    /// Remaining spares.
    pub fn n_spares(&self) -> usize {
        self.spares.len()
    }

    fn next_sne(&mut self) -> Result<usize> {
        let idx = self.next % self.snes.len();
        self.next = self.next.wrapping_add(1);
        if self.snes[idx].is_worn() {
            match self.config.wear_policy {
                WearPolicy::Ignore => {}
                WearPolicy::Rotate => {
                    if let Some(spare) = self.spares.pop() {
                        self.snes[idx] = spare;
                    } else {
                        let dev = self.snes[idx].device();
                        return Err(Error::DeviceWorn { row: 0, col: idx, cycles: dev.cycles() });
                    }
                }
                WearPolicy::Fail => {
                    let dev = self.snes[idx].device();
                    return Err(Error::DeviceWorn { row: 0, col: idx, cycles: dev.cycles() });
                }
            }
        }
        Ok(idx)
    }

    /// Encode `p` on the next SNE (uncorrelated w.r.t. other calls).
    pub fn encode(&mut self, p: f64) -> Result<Bitstream> {
        let n_bits = self.config.n_bits;
        let idx = self.next_sne()?;
        let Self { snes, ledger, rng, .. } = self;
        snes[idx].encode(p, n_bits, ledger, rng)
    }

    /// Encode `p` with an explicit bit length.
    pub fn encode_with_len(&mut self, p: f64, n_bits: usize) -> Result<Bitstream> {
        let idx = self.next_sne()?;
        let Self { snes, ledger, rng, .. } = self;
        snes[idx].encode(p, n_bits, ledger, rng)
    }

    /// Encode a group of mutually **uncorrelated** streams (parallel SNEs).
    pub fn encode_group(&mut self, probs: &[f64]) -> Result<Vec<Bitstream>> {
        probs.iter().map(|&p| self.encode(p)).collect()
    }

    /// Encode a group of mutually uncorrelated streams into one packed
    /// word buffer — the grouped, allocation-free entry the batched
    /// decision engine uses ([`crate::bayes::BatchedInference`] /
    /// [`crate::bayes::BatchedFusion`]).
    ///
    /// Stream `j` occupies `out[j*W .. (j+1)*W]` with
    /// `W = n_bits.div_ceil(64)`; `out.len()` must be `probs.len() * W`.
    /// SNEs are drawn through the same round-robin and the RNG is
    /// consumed in the same order as repeated [`Self::encode`] calls, so
    /// the packed bits are bit-identical to the single-call path.
    pub fn encode_group_into(&mut self, probs: &[f64], out: &mut [u64]) -> Result<()> {
        let n_bits = self.config.n_bits;
        let w = n_bits.div_ceil(64);
        if out.len() != probs.len() * w {
            return Err(Error::LengthMismatch {
                lhs: out.len() * 64,
                rhs: probs.len() * w * 64,
            });
        }
        for (j, &p) in probs.iter().enumerate() {
            let idx = self.next_sne()?;
            let Self { snes, ledger, rng, .. } = self;
            snes[idx].encode_into_words(p, n_bits, &mut out[j * w..(j + 1) * w], ledger, rng)?;
        }
        Ok(())
    }

    /// Encode a group of maximally **correlated** streams (one shared SNE).
    pub fn encode_correlated(&mut self, probs: &[f64]) -> Result<Vec<Bitstream>> {
        let n_bits = self.config.n_bits;
        let idx = self.next_sne()?;
        let Self { snes, ledger, rng, .. } = self;
        snes[idx].encode_correlated(probs, n_bits, ledger, rng)
    }

    /// Begin a **chunked** grouped encode: the anytime evaluator's entry
    /// ([`crate::network::NetlistEvaluator::evaluate_anytime`]). SNEs are
    /// drawn through the same round-robin as [`Self::encode_group_into`],
    /// and each stream gets an RNG cursor positioned exactly where the
    /// whole-stream encode would read its words — so the bits emitted by
    /// [`Self::encode_group_chunk_into`] are **bit-identical** to the
    /// corresponding slice of the whole-stream encode (pinned by tests).
    ///
    /// The bank's own RNG advances to the *post-group* state up front:
    /// the virtual stream exists in full, and an early exit simply stops
    /// reading (and pulsing) it. Later decisions on this bank are
    /// therefore bit-reproducible no matter where an anytime decision
    /// stopped.
    ///
    /// With `drift_coupling != 0` the pulse-by-pulse device model's RNG
    /// consumption is data-dependent, so chunk boundaries cannot
    /// reposition the RNG without doing the pulses: the full streams are
    /// staged here (wear and ledger recorded in full) and chunks are
    /// served from the staging buffer — anytime then trims the readout,
    /// not the pulses.
    pub fn begin_group_chunks(&mut self, probs: &[f64]) -> Result<GroupChunkEncoder> {
        for &p in probs {
            Error::check_prob("p", p)?;
        }
        let n_bits = self.config.n_bits;
        let w = n_bits.div_ceil(64);
        if self.config.params.drift_coupling != 0.0 {
            let mut staged = vec![0u64; probs.len() * w];
            self.encode_group_into(probs, &mut staged)?;
            return Ok(GroupChunkEncoder {
                source: ChunkSource::Staged(staged),
                n_streams: probs.len(),
                n_bits,
                words_total: w,
                start_word: 0,
                end_word: w,
                next_word: 0,
            });
        }
        let mut streams = Vec::with_capacity(probs.len());
        for &p in probs {
            let sne = self.next_sne()?;
            // The cursor starts where the bank RNG is now; the bank RNG
            // then skips exactly this stream's fast-path draw count
            // ((16 − lo) words per packed word — see `encode_into_words`)
            // so the next stream's cursor, and the bank's final state,
            // match the whole-stream encode.
            let rng = self.rng.clone();
            let (q, lo) = quantize(p);
            for _ in 0..(16 - lo) as usize * w {
                self.rng.next_u64();
            }
            streams.push(StreamCursor { rng, sne, q, lo });
        }
        Ok(GroupChunkEncoder {
            source: ChunkSource::Live(streams),
            n_streams: probs.len(),
            n_bits,
            words_total: w,
            start_word: 0,
            end_word: w,
            next_word: 0,
        })
    }

    /// Begin a **sharded** grouped encode — the intra-decision parallel
    /// evaluator's entry
    /// ([`crate::network::NetlistEvaluator::set_threads`]). `bounds`
    /// must partition the packed word range `[0, W)` into contiguous
    /// non-empty spans; each span gets its own [`GroupChunkEncoder`]
    /// whose per-stream RNG cursors are positioned exactly where the
    /// whole-stream encode would read that span's first word — the
    /// chunk-cursor machinery of [`Self::begin_group_chunks`],
    /// generalized to arbitrary shard offsets. The shard encoders
    /// together emit the bit-identical stream set, and the bank RNG and
    /// SNE round-robin advance exactly as the whole-stream encode would,
    /// so later decisions are unaffected.
    ///
    /// Shard workers record nothing (they run bank-free through
    /// [`GroupChunkEncoder`]); wear and ledger are settled by
    /// [`Self::finish_group_shards`] after the shards join, in stream
    /// order, making the totals independent of shard interleaving. Wear
    /// *checks* all happen here at begin — the chunked path's documented
    /// timing.
    ///
    /// Only the ideal-device fast path can reposition cursors: with
    /// `drift_coupling != 0` the pulse walk's RNG consumption is
    /// data-dependent, and callers must fall back to single-shard
    /// staging via [`Self::begin_group_chunks`].
    pub fn begin_group_shards(
        &mut self,
        probs: &[f64],
        bounds: &[(usize, usize)],
    ) -> Result<GroupShardSession> {
        for &p in probs {
            Error::check_prob("p", p)?;
        }
        if self.config.params.drift_coupling != 0.0 {
            return Err(Error::Config(
                "begin_group_shards requires ideal devices (drift_coupling == 0); \
                 use begin_group_chunks (single-shard staging) instead"
                    .into(),
            ));
        }
        let n_bits = self.config.n_bits;
        let w = n_bits.div_ceil(64);
        let contiguous = bounds.first().is_some_and(|b| b.0 == 0)
            && bounds.last().is_some_and(|b| b.1 == w)
            && bounds.windows(2).all(|p| p[0].1 == p[1].0)
            && bounds.iter().all(|b| b.0 < b.1);
        if !contiguous {
            return Err(Error::Config(format!(
                "shard bounds must partition the {w}-word stream contiguously"
            )));
        }
        let mut snes = Vec::with_capacity(probs.len());
        let mut cursors: Vec<Vec<StreamCursor>> =
            bounds.iter().map(|_| Vec::with_capacity(probs.len())).collect();
        for &p in probs {
            let sne = self.next_sne()?;
            let (q, lo) = quantize(p);
            let draws = (16 - lo) as usize;
            // Walk this stream's RNG span once, snapshotting a cursor at
            // every shard boundary: total consumption matches the
            // whole-stream encode exactly.
            let mut word = 0usize;
            for (cur, &(start, _)) in cursors.iter_mut().zip(bounds) {
                for _ in 0..(start - word) * draws {
                    self.rng.next_u64();
                }
                word = start;
                cur.push(StreamCursor { rng: self.rng.clone(), sne, q, lo });
            }
            for _ in 0..(w - word) * draws {
                self.rng.next_u64();
            }
            snes.push(sne);
        }
        let shards = cursors
            .into_iter()
            .zip(bounds)
            .map(|(streams, &(start, end))| GroupChunkEncoder {
                source: ChunkSource::Live(streams),
                n_streams: probs.len(),
                n_bits,
                words_total: w,
                start_word: start,
                end_word: end,
                next_word: start,
            })
            .collect();
        Ok(GroupShardSession { shards, snes })
    }

    /// Settle the wear and ledger accounting of a sharded grouped encode
    /// ([`Self::begin_group_shards`]): `snes` are the session's
    /// per-stream device indices and `switches[j]` is stream `j`'s
    /// switch total summed across shards. Applied in stream order with
    /// one energy add per stream — the exact accounting sequence of
    /// [`Self::encode_group_into`] — so the ledger is bit-identical to
    /// the single-thread sweep no matter how many shards ran.
    pub fn finish_group_shards(&mut self, snes: &[usize], switches: &[u64]) {
        let energy = self.config.params.switch_energy_nj;
        let n_bits = self.config.n_bits as u64;
        for (&sne, &sw) in snes.iter().zip(switches) {
            self.snes[sne].device.record_switches(sw);
            self.ledger.pulses += n_bits;
            self.ledger.switch_events += sw;
            self.ledger.energy_nj += sw as f64 * energy;
        }
    }

    /// Encode the next chunk of every stream in `enc` into `out`:
    /// stream `j`'s words land at `out[j*cw .. j*cw + n]` where
    /// `cw = out.len() / n_streams` and `n` is the returned word count
    /// (0 once the streams are exhausted). Bits and ledger pulse/switch
    /// totals are identical to the corresponding word slice of
    /// [`Self::encode_group_into`]; abandoning the encoder mid-stream
    /// leaves the remaining pulses unspent (bits saved = energy saved),
    /// while the bank RNG was already advanced at
    /// [`Self::begin_group_chunks`].
    ///
    /// One deliberate divergence from the whole-stream path: wear
    /// *checks* (`next_sne`) all happen at begin, before any of this
    /// group's switches are recorded — so a device worn out *by this
    /// very group* trips the wear policy on the **next** decision rather
    /// than mid-group. Emitted bits are unaffected (the ideal-device
    /// fast path derives them from the RNG cursor, not the device), and
    /// the recorded switch totals are identical.
    pub fn encode_group_chunk_into(
        &mut self,
        enc: &mut GroupChunkEncoder,
        out: &mut [u64],
    ) -> Result<usize> {
        if enc.n_streams == 0 || enc.is_done() {
            return Ok(0);
        }
        if out.is_empty() || out.len() % enc.n_streams != 0 {
            return Err(Error::LengthMismatch { lhs: out.len(), rhs: enc.n_streams });
        }
        let cw = out.len() / enc.n_streams;
        let words = cw.min(enc.end_word - enc.next_word);
        let is_tail = enc.next_word + words == enc.words_total;
        let tail = is_tail.then(|| tail_word_mask(enc.n_bits));
        let chunk_bits = if is_tail { enc.n_bits - enc.next_word * 64 } else { words * 64 };
        match &mut enc.source {
            ChunkSource::Live(streams) => {
                let energy = self.config.params.switch_energy_nj;
                for (j, cur) in streams.iter_mut().enumerate() {
                    // The binary-expansion construction of
                    // `encode_into_words`, replayed from this stream's
                    // cursor.
                    let switches = cur.emit(&mut out[j * cw..j * cw + words], tail);
                    self.snes[cur.sne].device.record_switches(switches);
                    self.ledger.pulses += chunk_bits as u64;
                    self.ledger.switch_events += switches;
                    self.ledger.energy_nj += switches as f64 * energy;
                }
            }
            ChunkSource::Staged(staged) => {
                for j in 0..enc.n_streams {
                    let src = &staged[j * enc.words_total + enc.next_word..][..words];
                    out[j * cw..j * cw + words].copy_from_slice(src);
                }
            }
        }
        enc.next_word += words;
        Ok(words)
    }

    /// Mark one complete decision on the ledger (advances the virtual
    /// hardware clock by one stream time — all SNEs pulse in parallel).
    pub fn finish_decision(&mut self) {
        self.ledger.record_decision(self.config.n_bits);
    }

    /// [`Self::finish_decision`] with an explicit bit count: the anytime
    /// evaluator's early-exit path records only the bits actually
    /// streamed, so the virtual hardware clock reflects the time the
    /// truncated decision really took.
    pub fn finish_decision_bits(&mut self, n_bits: usize) {
        self.ledger.record_decision(n_bits);
    }

    /// Direct access to the RNG (used by gates needing auxiliary select
    /// streams and by tests).
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stochastic::{pearson, scc};

    #[test]
    fn encode_hits_target_probability() {
        let mut bank = SneBank::new(
            SneConfig { n_bits: 20_000, ..Default::default() },
            7,
        )
        .unwrap();
        for &p in &[0.05, 0.3, 0.57, 0.72, 0.95] {
            let s = bank.encode(p).unwrap();
            assert!((s.value() - p).abs() < 0.015, "p={p} got {}", s.value());
        }
    }

    #[test]
    fn parallel_streams_are_uncorrelated() {
        let mut bank =
            SneBank::new(SneConfig { n_bits: 20_000, ..Default::default() }, 8).unwrap();
        let g = bank.encode_group(&[0.5, 0.5]).unwrap();
        let rho = pearson(&g[0], &g[1]).unwrap();
        assert!(rho.abs() < 0.03, "pearson {rho}");
        let s = scc(&g[0], &g[1]).unwrap();
        assert!(s.abs() < 0.05, "scc {s}");
    }

    #[test]
    fn shared_sne_streams_are_maximally_correlated() {
        let mut bank =
            SneBank::new(SneConfig { n_bits: 20_000, ..Default::default() }, 9).unwrap();
        let g = bank.encode_correlated(&[0.3, 0.7]).unwrap();
        assert!((g[0].value() - 0.3).abs() < 0.02);
        assert!((g[1].value() - 0.7).abs() < 0.02);
        // Comonotone: the 0.3-stream is a subset of the 0.7-stream.
        let s = scc(&g[0], &g[1]).unwrap();
        assert!(s > 0.95, "scc {s}");
        let and = g[0].and(&g[1]).unwrap();
        assert!((and.value() - 0.3).abs() < 0.02, "min() law broken");
    }

    #[test]
    fn correlated_refs_invert_fig2c() {
        let bank = SneBank::seeded(1);
        let sne = &bank.snes[0];
        for &p in &[0.1, 0.5, 0.9] {
            let vref = sne.ref_for(p);
            // Fig. 2c: P = 1 − σ(11.5 (V_ref − 0.57)) (nominal device).
            let d2d = sne.device().vth_mu() - 2.08; // ref_for is per-device
            let _ = d2d;
            let p_back = 1.0 - 1.0 / (1.0 + (-(vref - 0.57) / (1.0 / 11.5)).exp());
            assert!((p_back - p).abs() < 1e-9, "p={p} back={p_back}");
        }
    }

    #[test]
    fn wear_rotation_swaps_in_spares() {
        let params = DeviceParams { endurance_cycles: 50, ..Default::default() };
        let cfg = SneConfig { n_bits: 100, n_snes: 1, params, ..Default::default() };
        let mut bank = SneBank::new(cfg, 3).unwrap();
        assert_eq!(bank.n_spares(), 1);
        // Each 100-bit encode at p=0.99 burns ~99 cycles > the 50 budget.
        bank.encode(0.99).unwrap();
        bank.encode(0.99).unwrap(); // triggers rotation onto the spare
        assert_eq!(bank.n_spares(), 0);
        // The spare is now worn too and nothing is left -> error.
        let err = bank.encode(0.99).unwrap_err();
        assert!(matches!(err, Error::DeviceWorn { .. }));
    }

    #[test]
    fn wear_fail_policy_errors_immediately() {
        let params = DeviceParams { endurance_cycles: 10, ..Default::default() };
        let cfg = SneConfig {
            n_bits: 100,
            n_snes: 1,
            params,
            wear_policy: WearPolicy::Fail,
        };
        let mut bank = SneBank::new(cfg, 4).unwrap();
        bank.encode(0.99).unwrap();
        assert!(bank.encode(0.99).is_err());
    }

    #[test]
    fn ledger_tracks_energy_and_time() {
        let mut bank = SneBank::seeded(5);
        let s = bank.encode(0.5).unwrap();
        bank.finish_decision();
        let l = bank.ledger();
        assert_eq!(l.pulses, 100);
        assert_eq!(l.switch_events as usize, s.count_ones());
        assert!((l.clock.elapsed_ms() - 0.4).abs() < 1e-12);
        assert!((l.energy_nj - 0.16 * s.count_ones() as f64).abs() < 1e-9);
    }

    #[test]
    fn encode_group_into_matches_single_calls() {
        let mut a = SneBank::seeded(77);
        let mut b = SneBank::seeded(77);
        let probs = [0.3, 0.57, 0.72];
        let singles: Vec<Bitstream> = probs.iter().map(|&p| a.encode(p).unwrap()).collect();
        let w = b.n_bits().div_ceil(64);
        let mut packed = vec![0u64; probs.len() * w];
        b.encode_group_into(&probs, &mut packed).unwrap();
        for (j, s) in singles.iter().enumerate() {
            assert_eq!(&packed[j * w..(j + 1) * w], s.words(), "stream {j} diverged");
        }
        // Same ledger accounting on both paths.
        assert_eq!(a.ledger().pulses, b.ledger().pulses);
        assert_eq!(a.ledger().switch_events, b.ledger().switch_events);
        // Wrong buffer size is rejected.
        let mut tiny = [0u64; 1];
        assert!(b.encode_group_into(&probs, &mut tiny).is_err());
    }

    #[test]
    fn chunked_group_encode_is_bit_identical_to_whole_stream() {
        // Odd lengths stress the tail mask; probs include the q = 0 and
        // q = 65536 extremes (no RNG draws) between ordinary streams so
        // the per-stream cursor positioning is exercised across them.
        let probs = [0.3, 0.0, 0.57, 1.0, 0.72];
        for n_bits in [64usize, 100, 130, 1000, 1024] {
            let cfg = SneConfig { n_bits, ..Default::default() };
            let mut whole = SneBank::new(cfg.clone(), 99).unwrap();
            let mut chunked = SneBank::new(cfg, 99).unwrap();
            let w = n_bits.div_ceil(64);
            let mut expect = vec![0u64; probs.len() * w];
            whole.encode_group_into(&probs, &mut expect).unwrap();

            let mut enc = chunked.begin_group_chunks(&probs).unwrap();
            assert_eq!(enc.n_streams(), probs.len());
            assert_eq!(enc.bits_total(), n_bits);
            let cw = 2usize.min(w); // tiny chunks stress the boundaries
            let mut got = vec![0u64; probs.len() * w];
            let mut chunk = vec![0u64; probs.len() * cw];
            let mut done = 0usize;
            loop {
                let n = chunked.encode_group_chunk_into(&mut enc, &mut chunk).unwrap();
                if n == 0 {
                    break;
                }
                for j in 0..probs.len() {
                    got[j * w + done..j * w + done + n]
                        .copy_from_slice(&chunk[j * cw..j * cw + n]);
                }
                done += n;
            }
            assert!(enc.is_done());
            assert_eq!(enc.bits_done(), n_bits);
            assert_eq!(got, expect, "chunked bits diverged at {n_bits} bits");
            // Same wear/energy accounting on both paths.
            assert_eq!(whole.ledger().pulses, chunked.ledger().pulses);
            assert_eq!(whole.ledger().switch_events, chunked.ledger().switch_events);
            assert!((whole.ledger().energy_nj - chunked.ledger().energy_nj).abs() < 1e-9);
            // Both banks sit at the identical RNG/round-robin position:
            // the next decision's stream must match bit for bit.
            let a = whole.encode(0.41).unwrap();
            let b = chunked.encode(0.41).unwrap();
            assert_eq!(a, b, "post-encode bank state diverged at {n_bits} bits");
        }
    }

    #[test]
    fn abandoned_chunk_encode_keeps_later_decisions_identical() {
        let probs = [0.3, 0.57, 0.72];
        let cfg = SneConfig { n_bits: 1024, ..Default::default() };
        let mut whole = SneBank::new(cfg.clone(), 7).unwrap();
        let mut early = SneBank::new(cfg, 7).unwrap();
        let w = 1024usize.div_ceil(64);
        let mut buf = vec![0u64; probs.len() * w];
        whole.encode_group_into(&probs, &mut buf).unwrap();
        whole.finish_decision();

        // Early exit: read one 4-word chunk, then abandon the encoder.
        let mut enc = early.begin_group_chunks(&probs).unwrap();
        let mut chunk = vec![0u64; probs.len() * 4];
        let n = early.encode_group_chunk_into(&mut enc, &mut chunk).unwrap();
        assert_eq!(n, 4);
        let bits_done = enc.bits_done();
        drop(enc);
        early.finish_decision_bits(bits_done);

        // Fewer pulses were spent…
        assert!(early.ledger().pulses < whole.ledger().pulses);
        assert!(early.ledger().clock.elapsed_ns() < whole.ledger().clock.elapsed_ns());
        // …but the RNG cursor advanced past the whole virtual stream, so
        // the next decision is bit-identical on both banks.
        let a = whole.encode_group(&probs).unwrap();
        let b = early.encode_group(&probs).unwrap();
        assert_eq!(a, b, "early exit desynced the bank");
    }

    #[test]
    fn chunk_encode_rejects_bad_buffers_and_probs() {
        let mut bank = SneBank::seeded(3);
        assert!(bank.begin_group_chunks(&[0.5, 1.5]).is_err());
        let mut enc = bank.begin_group_chunks(&[0.5, 0.6]).unwrap();
        // Buffer not divisible by the stream count.
        let mut bad = [0u64; 3];
        assert!(bank.encode_group_chunk_into(&mut enc, &mut bad).is_err());
        let mut empty: [u64; 0] = [];
        assert!(bank.encode_group_chunk_into(&mut enc, &mut empty).is_err());
        // Exhaustion returns 0 instead of erroring.
        let mut ok = [0u64; 4];
        while bank.encode_group_chunk_into(&mut enc, &mut ok).unwrap() > 0 {}
        assert!(enc.is_done());
        assert_eq!(bank.encode_group_chunk_into(&mut enc, &mut ok).unwrap(), 0);
    }

    #[test]
    fn chunked_encode_stages_whole_streams_under_drift() {
        // Nonideal devices pulse bit by bit: the chunked path stages the
        // full streams at begin (identical bits, full wear recorded) and
        // serves chunks from the buffer.
        let params = DeviceParams { drift_coupling: 0.05, ..Default::default() };
        let cfg = SneConfig { n_bits: 256, params, ..Default::default() };
        let mut whole = SneBank::new(cfg.clone(), 11).unwrap();
        let mut chunked = SneBank::new(cfg, 11).unwrap();
        let probs = [0.4, 0.8];
        let w = 4;
        let mut expect = vec![0u64; probs.len() * w];
        whole.encode_group_into(&probs, &mut expect).unwrap();
        let mut enc = chunked.begin_group_chunks(&probs).unwrap();
        // Ledger already reflects the full pulse walk.
        assert_eq!(whole.ledger().pulses, chunked.ledger().pulses);
        let mut got = vec![0u64; probs.len() * w];
        let mut chunk = vec![0u64; probs.len() * 2];
        let mut done = 0usize;
        loop {
            let n = chunked.encode_group_chunk_into(&mut enc, &mut chunk).unwrap();
            if n == 0 {
                break;
            }
            for j in 0..probs.len() {
                got[j * w + done..j * w + done + n]
                    .copy_from_slice(&chunk[j * 2..j * 2 + n]);
            }
            done += n;
        }
        assert_eq!(got, expect);
    }

    #[test]
    fn sharded_group_encode_is_bit_identical_to_whole_stream() {
        // Shard-offset cursors must reproduce the whole-stream bits,
        // the ledger (via deferred settlement), and the bank's
        // post-group RNG/round-robin state, at every shard layout and
        // odd tail length.
        let probs = [0.3, 0.0, 0.57, 1.0, 0.72];
        for n_bits in [512usize, 530, 1000, 1024, 4096] {
            let w = n_bits.div_ceil(64);
            for bounds in [vec![(0, w)], vec![(0, w / 2), (w / 2, w)], {
                // Uneven three-way split.
                let a = w / 3;
                let b = 2 * w / 3;
                vec![(0, a.max(1)), (a.max(1), b.max(2)), (b.max(2), w)]
            }] {
                let cfg = SneConfig { n_bits, ..Default::default() };
                let mut whole = SneBank::new(cfg.clone(), 99).unwrap();
                let mut sharded = SneBank::new(cfg, 99).unwrap();
                let mut expect = vec![0u64; probs.len() * w];
                whole.encode_group_into(&probs, &mut expect).unwrap();

                let session = sharded.begin_group_shards(&probs, &bounds).unwrap();
                assert_eq!(session.n_shards(), bounds.len());
                let (mut shards, snes) = session.into_parts();
                let mut got = vec![0u64; probs.len() * w];
                let mut switches = vec![0u64; probs.len()];
                for (enc, &(start, end)) in shards.iter_mut().zip(&bounds) {
                    let span = end - start;
                    let mut buf = vec![0u64; probs.len() * span];
                    let n = enc.encode_chunk_detached(&mut buf, &mut switches);
                    assert_eq!(n, span);
                    assert!(enc.is_done());
                    assert_eq!(enc.bits_done(), (end * 64).min(n_bits) - start * 64);
                    for j in 0..probs.len() {
                        got[j * w + start..j * w + end]
                            .copy_from_slice(&buf[j * span..(j + 1) * span]);
                    }
                }
                sharded.finish_group_shards(&snes, &switches);
                assert_eq!(got, expect, "sharded bits diverged at {n_bits} bits");
                assert_eq!(whole.ledger().pulses, sharded.ledger().pulses);
                assert_eq!(whole.ledger().switch_events, sharded.ledger().switch_events);
                assert_eq!(
                    whole.ledger().energy_nj.to_bits(),
                    sharded.ledger().energy_nj.to_bits(),
                    "ledger energy must match bit-for-bit"
                );
                // Identical post-group bank state: next decision matches.
                let a = whole.encode(0.41).unwrap();
                let b = sharded.encode(0.41).unwrap();
                assert_eq!(a, b, "post-shard bank state diverged at {n_bits} bits");
            }
        }
    }

    #[test]
    fn shard_begin_rejects_bad_bounds_and_drift() {
        let mut bank = SneBank::seeded(4); // 100 bits -> 2 words
        for bad in [
            vec![],                  // empty
            vec![(0, 1)],            // does not reach the end
            vec![(1, 2)],            // does not start at 0
            vec![(0, 1), (1, 1)],    // empty span
            vec![(0, 2), (1, 2)],    // overlap
            vec![(0, 1), (2, 2)],    // gap (and empty)
        ] {
            let err = bank.begin_group_shards(&[0.5], &bad).unwrap_err();
            assert!(matches!(err, Error::Config(_)), "{bad:?} not rejected");
        }
        // Sanity: a valid partition on the same bank succeeds…
        assert!(bank.begin_group_shards(&[0.5], &[(0, 1), (1, 2)]).is_ok());
        // …and probabilities are validated before the bank is touched.
        assert!(bank.begin_group_shards(&[1.5], &[(0, 2)]).is_err());
        // Nonideal devices cannot reposition cursors: typed config error.
        let params = DeviceParams { drift_coupling: 0.05, ..Default::default() };
        let cfg = SneConfig { n_bits: 128, params, ..Default::default() };
        let mut drifty = SneBank::new(cfg, 5).unwrap();
        let err = drifty.begin_group_shards(&[0.5], &[(0, 2)]).unwrap_err();
        assert!(matches!(err, Error::Config(_)));
    }

    #[test]
    fn invalid_probability_rejected() {
        let mut bank = SneBank::seeded(6);
        assert!(bank.encode(1.2).is_err());
        assert!(bank.encode(-0.1).is_err());
        assert!(bank.encode_correlated(&[0.5, 1.5]).is_err());
    }

    #[test]
    fn config_validation() {
        assert!(SneConfig { n_bits: 0, ..Default::default() }.validate().is_err());
        assert!(SneConfig { n_snes: 0, ..Default::default() }.validate().is_err());
        assert!(SneConfig::default().validate().is_ok());
    }
}
