//! In-tree utility substrates.
//!
//! The build environment is fully offline with a minimal vendored crate
//! set, so the pieces a crates.io project would pull in (`rand`,
//! `serde`/`toml`, `clap`, `proptest`) are implemented here instead:
//!
//! * [`rng`] — seedable xoshiro256++ PRNG with normal/logistic samplers.
//! * [`stats`] — mean/std/quantile/histogram helpers shared by figures.
//! * [`tomlmini`] — the TOML subset used by the config system.
//! * [`proptest_lite`] — randomized property-test driver for the
//!   invariant suites.

pub mod proptest_lite;
pub mod rng;
pub mod stats;
pub mod tomlmini;

pub use rng::Rng;
