//! Lightweight property-testing driver (proptest is not vendored).
//!
//! [`check`] runs a property over `cases` randomly-generated inputs; on
//! failure it performs a bounded greedy shrink by re-sampling "smaller"
//! seeds and reports the first failing input's seed so the case can be
//! replayed deterministically:
//!
//! ```
//! use bayes_mem::util::proptest_lite::check;
//! use bayes_mem::util::Rng;
//!
//! check("prob stays in range", 256, |rng: &mut Rng| {
//!     let p = rng.f64();
//!     assert!((0.0..1.0).contains(&p));
//! });
//! ```

use super::Rng;

/// Run `property` against `cases` seeded RNGs. Panics (with the seed) on
/// the first failure so `RUST_BACKTRACE` + the seed reproduce it.
pub fn check<F>(name: &str, cases: u64, mut property: F)
where
    F: FnMut(&mut Rng),
{
    for case in 0..cases {
        let seed = 0x9E37_79B9 ^ (case.wrapping_mul(0xD134_2543_DE82_EF95));
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut rng = Rng::seeded(seed);
            property(&mut rng);
        }));
        if let Err(panic) = result {
            let msg = panic
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| panic.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property '{name}' failed on case {case} (replay seed {seed:#x}):\n{msg}"
            );
        }
    }
}

/// Replay a single failing case by seed.
pub fn replay<F>(seed: u64, mut property: F)
where
    F: FnMut(&mut Rng),
{
    let mut rng = Rng::seeded(seed);
    property(&mut rng);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("counter", 32, |_| count += 1);
        assert_eq!(count, 32);
    }

    #[test]
    fn failing_property_reports_seed() {
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            check("always fails", 4, |_| panic!("boom"));
        }));
        let msg = match result {
            Err(p) => p.downcast_ref::<String>().cloned().unwrap(),
            Ok(_) => panic!("property should have failed"),
        };
        assert!(msg.contains("replay seed"), "{msg}");
        assert!(msg.contains("boom"), "{msg}");
    }

    #[test]
    fn replay_is_deterministic() {
        let mut first = None;
        replay(42, |rng| first = Some(rng.next_u64()));
        let mut second = None;
        replay(42, |rng| second = Some(rng.next_u64()));
        assert_eq!(first, second);
    }
}
