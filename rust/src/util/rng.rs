//! Seedable PRNG: xoshiro256++ seeded through SplitMix64, plus the
//! distribution samplers the device model needs (normal, logistic) and
//! small conveniences (Bernoulli, ranges, index sampling).
//!
//! xoshiro256++ passes BigCrush, is trivially seedable/clonable, and emits
//! one `u64` per 4 rotate/xor ops — fast enough that bit-stream encoding
//! is memory-bound, not RNG-bound (see EXPERIMENTS.md §Perf).

/// Seedable, clonable PRNG (xoshiro256++).
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Box-Muller normal sample.
    spare_normal: Option<f64>,
}

impl Rng {
    /// Seed via SplitMix64 expansion (any seed, including 0, is fine).
    pub fn seeded(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Self { s: [next(), next(), next(), next()], spare_normal: None }
    }

    /// Split off an independently-seeded child RNG (for parallel workers).
    pub fn split(&mut self) -> Rng {
        Rng::seeded(self.next_u64() ^ 0xA5A5_5A5A_DEAD_BEEF)
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform `f64` in `[0, 1)` (53-bit resolution).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f64` in the open interval `(0, 1)` (safe for `ln`).
    #[inline]
    pub fn f64_open(&mut self) -> f64 {
        loop {
            let u = self.f64();
            if u > 0.0 {
                return u;
            }
        }
    }

    /// Bernoulli draw with probability `p`.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Uniform integer in `[0, n)`.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection-free-enough method.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform `f64` in `[lo, hi)`.
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in `[lo, hi)`.
    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi > lo);
        lo + self.below(hi - lo)
    }

    /// Standard normal via Box-Muller (with spare caching).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        let u1 = self.f64_open();
        let u2 = self.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.spare_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Normal with mean `mu`, std-dev `sigma`.
    #[inline]
    pub fn normal_with(&mut self, mu: f64, sigma: f64) -> f64 {
        mu + sigma * self.normal()
    }

    /// Standard logistic sample (location 0, scale 1).
    pub fn logistic(&mut self) -> f64 {
        let u = self.f64_open();
        (u / (1.0 - u)).ln()
    }

    /// Log-normal with log-domain parameters `mu`, `sigma`.
    pub fn log_normal(&mut self, mu: f64, sigma: f64) -> f64 {
        self.normal_with(mu, sigma).exp()
    }

    /// Sample `k` distinct indices from `[0, n)` (Floyd's algorithm).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let k = k.min(n);
        let mut chosen: Vec<usize> = Vec::with_capacity(k);
        for j in n - k..n {
            let t = self.below(j + 1);
            if chosen.contains(&t) {
                chosen.push(j);
            } else {
                chosen.push(t);
            }
        }
        chosen
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::{mean, std_dev};

    #[test]
    fn deterministic_and_seed_sensitive() {
        let mut a = Rng::seeded(1);
        let mut b = Rng::seeded(1);
        let mut c = Rng::seeded(2);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        let vc: Vec<u64> = (0..8).map(|_| c.next_u64()).collect();
        assert_eq!(va, vb);
        assert_ne!(va, vc);
    }

    #[test]
    fn uniform_moments() {
        let mut r = Rng::seeded(3);
        let xs: Vec<f64> = (0..100_000).map(|_| r.f64()).collect();
        assert!((mean(&xs) - 0.5).abs() < 0.005);
        assert!((std_dev(&xs) - (1.0f64 / 12.0).sqrt()).abs() < 0.005);
        assert!(xs.iter().all(|&x| (0.0..1.0).contains(&x)));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seeded(4);
        let xs: Vec<f64> = (0..100_000).map(|_| r.normal_with(2.08, 0.28)).collect();
        assert!((mean(&xs) - 2.08).abs() < 0.01);
        assert!((std_dev(&xs) - 0.28).abs() < 0.01);
    }

    #[test]
    fn logistic_moments() {
        // Var of standard logistic = π²/3.
        let mut r = Rng::seeded(5);
        let xs: Vec<f64> = (0..100_000).map(|_| r.logistic()).collect();
        assert!(mean(&xs).abs() < 0.03);
        let want = std::f64::consts::PI / 3f64.sqrt();
        assert!((std_dev(&xs) - want).abs() < 0.05);
    }

    #[test]
    fn bernoulli_frequency() {
        let mut r = Rng::seeded(6);
        let hits = (0..100_000).filter(|_| r.bernoulli(0.57)).count();
        assert!((hits as f64 / 1e5 - 0.57).abs() < 0.01);
    }

    #[test]
    fn below_is_uniform_and_in_range() {
        let mut r = Rng::seeded(7);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.below(10)] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "bucket {c}");
        }
    }

    #[test]
    fn sample_indices_distinct_and_bounded() {
        let mut r = Rng::seeded(8);
        for _ in 0..100 {
            let idx = r.sample_indices(144, 10);
            assert_eq!(idx.len(), 10);
            let mut sorted = idx.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), 10, "duplicates in {idx:?}");
            assert!(idx.iter().all(|&i| i < 144));
        }
        // k > n clamps.
        assert_eq!(r.sample_indices(3, 10).len(), 3);
    }

    #[test]
    fn split_streams_are_decorrelated() {
        let mut r = Rng::seeded(9);
        let mut child = r.split();
        let xs: Vec<f64> = (0..10_000).map(|_| r.f64()).collect();
        let ys: Vec<f64> = (0..10_000).map(|_| child.f64()).collect();
        let mx = mean(&xs);
        let my = mean(&ys);
        let cov: f64 = xs.iter().zip(&ys).map(|(x, y)| (x - mx) * (y - my)).sum::<f64>()
            / xs.len() as f64;
        let corr = cov / (std_dev(&xs) * std_dev(&ys));
        assert!(corr.abs() < 0.03, "corr {corr}");
    }
}
