//! Small statistics helpers shared by the device model, figure harnesses
//! and benches.

/// Arithmetic mean (0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Coefficient of variation `σ/μ` (0 when the mean is 0).
pub fn cov(xs: &[f64]) -> f64 {
    let m = mean(xs);
    if m == 0.0 {
        0.0
    } else {
        std_dev(xs) / m
    }
}

/// Quantile by linear interpolation on the sorted copy, `q ∈ [0, 1]`.
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = q.clamp(0.0, 1.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (v[hi] - v[lo]) * (pos - lo as f64)
    }
}

/// Fixed-width histogram over `[lo, hi)` with `bins` buckets. Out-of-range
/// samples clamp into the edge buckets.
pub fn histogram(xs: &[f64], lo: f64, hi: f64, bins: usize) -> Vec<usize> {
    let mut h = vec![0usize; bins.max(1)];
    if xs.is_empty() || hi <= lo {
        return h;
    }
    let w = (hi - lo) / bins as f64;
    for &x in xs {
        let idx = (((x - lo) / w) as isize).clamp(0, bins as isize - 1) as usize;
        h[idx] += 1;
    }
    h
}

/// Render a histogram as a unicode sparkline row (for figure CLI output).
pub fn sparkline(h: &[usize]) -> String {
    const GLYPHS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
    let max = h.iter().copied().max().unwrap_or(0).max(1);
    h.iter()
        .map(|&c| GLYPHS[(c * (GLYPHS.len() - 1) + max / 2) / max])
        .collect()
}

/// Half-width of the Wilson score interval for `ones` successes in `n`
/// Bernoulli trials at `z` standard-normal quantiles (`z = 3` ≈ 99.7 %
/// two-sided coverage). Returns 0.5 for `n = 0` — no information, the
/// interval is all of `[0, 1]`.
///
/// This is the anytime evaluator's confidence bound on the CORDIV
/// quotient density ([`crate::network::NetlistEvaluator::evaluate_anytime`]):
/// unlike the plain normal approximation it stays sane at extreme counts
/// (`ones = 0` or `ones = n` still give a positive width ~`z²/2n`).
pub fn wilson_half_width(ones: u64, n: u64, z: f64) -> f64 {
    if n == 0 {
        return 0.5;
    }
    let n = n as f64;
    let p = ones as f64 / n;
    let z2 = z * z;
    (z / (1.0 + z2 / n)) * (p * (1.0 - p) / n + z2 / (4.0 * n * n)).sqrt()
}

/// Least-squares fit of a logistic `1/(1+exp(-k(x-x0)))` to `(x, p)`
/// samples via logit-domain linear regression; returns `(k, x0)`.
/// Samples with `p` outside `(0.005, 0.995)` are ignored (logit blows up).
pub fn fit_sigmoid(points: &[(f64, f64)]) -> Option<(f64, f64)> {
    let usable: Vec<(f64, f64)> = points
        .iter()
        .filter(|(_, p)| (0.005..=0.995).contains(p))
        .map(|&(x, p)| (x, (p / (1.0 - p)).ln()))
        .collect();
    if usable.len() < 3 {
        return None;
    }
    let n = usable.len() as f64;
    let sx: f64 = usable.iter().map(|(x, _)| x).sum();
    let sy: f64 = usable.iter().map(|(_, y)| y).sum();
    let sxx: f64 = usable.iter().map(|(x, _)| x * x).sum();
    let sxy: f64 = usable.iter().map(|(x, y)| x * y).sum();
    let denom = n * sxx - sx * sx;
    if denom.abs() < 1e-12 {
        return None;
    }
    let k = (n * sxy - sx * sy) / denom;
    let b = (sy - k * sx) / n;
    if k == 0.0 {
        return None;
    }
    Some((k, -b / k))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_moments() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((std_dev(&xs) - (1.25f64).sqrt()).abs() < 1e-12);
        assert!((cov(&xs) - (1.25f64).sqrt() / 2.5).abs() < 1e-12);
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std_dev(&[1.0]), 0.0);
    }

    #[test]
    fn quantiles() {
        let xs = [4.0, 1.0, 3.0, 2.0];
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 1.0), 4.0);
        assert_eq!(quantile(&xs, 0.5), 2.5);
    }

    #[test]
    fn histogram_clamps_and_counts() {
        let xs = [-1.0, 0.1, 0.5, 0.9, 2.0];
        let h = histogram(&xs, 0.0, 1.0, 2);
        assert_eq!(h, vec![2, 3]); // -1 clamps low, 0.5/0.9/2.0 land high
        assert_eq!(histogram(&[], 0.0, 1.0, 4), vec![0, 0, 0, 0]);
    }

    #[test]
    fn sparkline_shape() {
        let s = sparkline(&[0, 5, 10]);
        assert_eq!(s.chars().count(), 3);
    }

    #[test]
    fn wilson_half_width_behaves() {
        // No data: the interval is everything.
        assert_eq!(wilson_half_width(0, 0, 3.0), 0.5);
        // Large n at p = 0.5 approaches z·√(p(1−p)/n).
        let hw = wilson_half_width(50_000, 100_000, 3.0);
        let approx = 3.0 * (0.25f64 / 100_000.0).sqrt();
        assert!((hw - approx).abs() < 1e-4, "hw {hw} vs {approx}");
        // Width shrinks with n.
        assert!(wilson_half_width(500, 1_000, 3.0) > wilson_half_width(5_000, 10_000, 3.0));
        // Extreme counts still give a positive, sane width.
        let hw0 = wilson_half_width(0, 1_000, 3.0);
        assert!(hw0 > 0.0 && hw0 < 0.02, "hw0 {hw0}");
        let hw1 = wilson_half_width(1_000, 1_000, 3.0);
        assert!((hw0 - hw1).abs() < 1e-12, "symmetric at the extremes");
        // Wider z, wider interval.
        assert!(wilson_half_width(300, 1_000, 3.0) > wilson_half_width(300, 1_000, 1.96));
    }

    #[test]
    fn sigmoid_fit_recovers_fig2b_constants() {
        // Sample the paper's own curve and refit.
        let pts: Vec<(f64, f64)> = (0..40)
            .map(|i| {
                let v = 1.0 + 2.5 * i as f64 / 39.0;
                (v, 1.0 / (1.0 + (-3.56 * (v - 2.24)).exp()))
            })
            .collect();
        let (k, x0) = fit_sigmoid(&pts).unwrap();
        assert!((k - 3.56).abs() < 0.05, "k {k}");
        assert!((x0 - 2.24).abs() < 0.02, "x0 {x0}");
    }

    #[test]
    fn sigmoid_fit_degenerate_inputs() {
        assert!(fit_sigmoid(&[(0.0, 0.5)]).is_none());
        assert!(fit_sigmoid(&[(0.0, 0.999), (1.0, 0.001), (2.0, 1.0)]).is_none());
    }
}
