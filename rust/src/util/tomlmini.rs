//! Minimal TOML-subset parser for the config system.
//!
//! Supports the subset config files actually use: `[section]` and
//! `[section.sub]` headers, `key = value` pairs with string / integer /
//! float / boolean / numeric-array values (`key = [0.1, 0.2]`, needed by
//! the CPT rows of network spec files), `#` comments and blank lines.
//! Keys are exposed flattened as `section.sub.key`.
//!
//! Numeric arrays may span multiple lines: an opening `[` with no `]` on
//! the same line accumulates subsequent lines (comments stripped, blank
//! lines skipped) until one *ends* with `]`. Scene-scale CPTs need this —
//! a 12-parent node has 4096 rows. A single trailing comma before the
//! closing `]` is tolerated in the multi-line form only; single-line
//! arrays stay strict.

use std::collections::BTreeMap;

use crate::{Error, Result};

/// A parsed scalar value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    /// Quoted string.
    Str(String),
    /// Integer literal.
    Int(i64),
    /// Float literal.
    Float(f64),
    /// `true` / `false`.
    Bool(bool),
    /// Single-line numeric array `[0.1, 0.2]` (ints widen to floats).
    Array(Vec<f64>),
}

impl Value {
    /// As f64 (ints widen).
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    /// As i64.
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    /// As bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// As string slice.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    /// As numeric array.
    pub fn as_f64_array(&self) -> Option<&[f64]> {
        match self {
            Value::Array(v) => Some(v),
            _ => None,
        }
    }
}

/// A flattened `section.key → value` document.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Document {
    entries: BTreeMap<String, Value>,
}

impl Document {
    /// Parse a TOML-subset string.
    pub fn parse(text: &str) -> Result<Self> {
        let mut entries = BTreeMap::new();
        let mut section = String::new();
        let lines: Vec<&str> = text.lines().collect();
        let mut i = 0;
        while i < lines.len() {
            let lineno = i;
            let line = strip_comment(lines[i]).trim();
            i += 1;
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| Error::Toml(format!("line {}: unterminated section", lineno + 1)))?
                    .trim();
                if name.is_empty() {
                    return Err(Error::Toml(format!("line {}: empty section name", lineno + 1)));
                }
                section = name.to_string();
                continue;
            }
            let (key, value) = line.split_once('=').ok_or_else(|| {
                Error::Toml(format!("line {}: expected `key = value`", lineno + 1))
            })?;
            let key = key.trim();
            if key.is_empty() {
                return Err(Error::Toml(format!("line {}: empty key", lineno + 1)));
            }
            let full = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            let mut value = value.trim().to_string();
            // Multi-line array: `[` opened but not closed on this line.
            // Accumulate until a line *ends* with `]` (after comment
            // stripping); hitting EOF first is a typed error naming the
            // line the array opened on.
            let multiline = value.starts_with('[') && !value.ends_with(']');
            if multiline {
                loop {
                    if i >= lines.len() {
                        return Err(Error::Toml(format!(
                            "line {}: array opened here is never closed (missing `]`)",
                            lineno + 1
                        )));
                    }
                    let cont = strip_comment(lines[i]).trim();
                    i += 1;
                    if cont.is_empty() {
                        continue;
                    }
                    value.push(' ');
                    value.push_str(cont);
                    if cont.ends_with(']') {
                        break;
                    }
                }
            }
            let parsed = if multiline {
                parse_multiline_array(&value)
            } else {
                parse_value(&value)
            }
            .ok_or_else(|| Error::Toml(format!("line {}: bad value {value:?}", lineno + 1)))?;
            entries.insert(full, parsed);
        }
        Ok(Self { entries })
    }

    /// Load from a file path.
    pub fn load(path: &std::path::Path) -> Result<Self> {
        Self::parse(&std::fs::read_to_string(path)?)
    }

    /// Raw value lookup by flattened key.
    pub fn get(&self, key: &str) -> Option<&Value> {
        self.entries.get(key)
    }

    /// Typed getters with default fallbacks.
    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(Value::as_f64).unwrap_or(default)
    }

    /// Integer-typed getter.
    pub fn i64_or(&self, key: &str, default: i64) -> i64 {
        self.get(key).and_then(Value::as_i64).unwrap_or(default)
    }

    /// usize-typed getter (negative values fall back to the default).
    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        match self.get(key).and_then(Value::as_i64) {
            Some(v) if v >= 0 => v as usize,
            _ => default,
        }
    }

    /// Bool-typed getter.
    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(Value::as_bool).unwrap_or(default)
    }

    /// String-typed getter.
    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).and_then(Value::as_str).unwrap_or(default)
    }

    /// All keys (flattened, sorted).
    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.entries.keys().map(String::as_str)
    }

    /// Keys not in `known` — config-validation support.
    pub fn unknown_keys<'a>(&'a self, known: &[&str]) -> Vec<&'a str> {
        self.keys().filter(|k| !known.contains(k)).collect()
    }
}

fn strip_comment(line: &str) -> &str {
    // Respect `#` inside quoted strings.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Option<Value> {
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest.strip_suffix('"')?;
        return Some(Value::Str(inner.to_string()));
    }
    if let Some(rest) = s.strip_prefix('[') {
        let inner = rest.strip_suffix(']')?.trim();
        if inner.is_empty() {
            return Some(Value::Array(Vec::new()));
        }
        let mut vals = Vec::new();
        for item in inner.split(',') {
            let cleaned = item.trim().replace('_', "");
            vals.push(cleaned.parse::<f64>().ok()?);
        }
        return Some(Value::Array(vals));
    }
    match s {
        "true" => return Some(Value::Bool(true)),
        "false" => return Some(Value::Bool(false)),
        _ => {}
    }
    parse_scalar_number(s)
}

/// The reassembled multi-line form: same numeric-array grammar, plus one
/// tolerated trailing comma before the closing `]` (the natural shape of
/// a generated row-per-line CPT dump).
fn parse_multiline_array(s: &str) -> Option<Value> {
    let inner = s.strip_prefix('[')?.strip_suffix(']')?.trim();
    let inner = inner.strip_suffix(',').unwrap_or(inner).trim();
    if inner.is_empty() {
        return Some(Value::Array(Vec::new()));
    }
    let mut vals = Vec::new();
    for item in inner.split(',') {
        let cleaned = item.trim().replace('_', "");
        vals.push(cleaned.parse::<f64>().ok()?);
    }
    Some(Value::Array(vals))
}

fn parse_scalar_number(s: &str) -> Option<Value> {
    let cleaned = s.replace('_', "");
    if let Ok(i) = cleaned.parse::<i64>() {
        return Some(Value::Int(i));
    }
    if let Ok(f) = cleaned.parse::<f64>() {
        return Some(Value::Float(f));
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# bayes-mem config
title = "demo"

[sne]
n_bits = 100
n_snes = 16

[device]
vth_mean = 2.08     # volts
drift_coupling = 0.0
ideal = true

[coordinator.batcher]
max_batch = 32
deadline_us = 1_000
"#;

    #[test]
    fn parses_sections_and_types() {
        let d = Document::parse(SAMPLE).unwrap();
        assert_eq!(d.str_or("title", ""), "demo");
        assert_eq!(d.usize_or("sne.n_bits", 0), 100);
        assert_eq!(d.f64_or("device.vth_mean", 0.0), 2.08);
        assert!(d.bool_or("device.ideal", false));
        assert_eq!(d.i64_or("coordinator.batcher.deadline_us", 0), 1000);
    }

    #[test]
    fn defaults_apply_for_missing_keys() {
        let d = Document::parse(SAMPLE).unwrap();
        assert_eq!(d.usize_or("sne.missing", 7), 7);
        assert_eq!(d.f64_or("nope", 1.5), 1.5);
        assert!(!d.bool_or("device.missing", false));
    }

    #[test]
    fn comments_inside_strings_are_kept() {
        let d = Document::parse(r##"name = "a # b" # trailing"##).unwrap();
        assert_eq!(d.str_or("name", ""), "a # b");
    }

    #[test]
    fn error_cases() {
        assert!(Document::parse("[unterminated").is_err());
        assert!(Document::parse("= 5").is_err());
        assert!(Document::parse("key = what?").is_err());
        assert!(Document::parse("[]").is_err());
        assert!(Document::parse("novalue").is_err());
    }

    #[test]
    fn float_arrays_parse() {
        let d = Document::parse("[cpt]\nrow = [0.1, 0.2, 1, 0.9]\nempty = []").unwrap();
        assert_eq!(d.get("cpt.row").unwrap().as_f64_array(), Some(&[0.1, 0.2, 1.0, 0.9][..]));
        assert_eq!(d.get("cpt.empty").unwrap().as_f64_array(), Some(&[][..]));
        // Underscore separators widen like scalar numbers do.
        let d = Document::parse("x = [1_000, 0.5]").unwrap();
        assert_eq!(d.get("x").unwrap().as_f64_array(), Some(&[1000.0, 0.5][..]));
        // Arrays are not scalars.
        assert!(d.get("x").unwrap().as_f64().is_none());
    }

    #[test]
    fn malformed_arrays_are_errors() {
        assert!(Document::parse("x = [0.1, 0.2").is_err()); // unterminated at EOF
        assert!(Document::parse("x = [0.1, oops]").is_err()); // non-numeric item
        assert!(Document::parse("x = [0.1 0.2]").is_err()); // missing comma
        assert!(Document::parse("x = [0.1,]").is_err()); // trailing comma (single-line)
        assert!(Document::parse("x = [,]").is_err()); // empty items
    }

    #[test]
    fn multiline_arrays_parse() {
        let d = Document::parse(
            "[node]\ncpt = [\n  0.1, 0.2, # first rows\n\n  0.3, 0.4,\n]\nafter = 7",
        )
        .unwrap();
        assert_eq!(
            d.get("node.cpt").unwrap().as_f64_array(),
            Some(&[0.1, 0.2, 0.3, 0.4][..])
        );
        // The continuation lines were consumed: parsing resumes cleanly.
        assert_eq!(d.i64_or("node.after", 0), 7);
        // Items may close on the last item's line, comma or not.
        let d = Document::parse("x = [1,\n2,\n3]").unwrap();
        assert_eq!(d.get("x").unwrap().as_f64_array(), Some(&[1.0, 2.0, 3.0][..]));
        let d = Document::parse("x = [\n]").unwrap();
        assert_eq!(d.get("x").unwrap().as_f64_array(), Some(&[][..]));
    }

    #[test]
    fn multiline_array_errors_name_the_opening_line() {
        // EOF before `]`: the error points at the line the array opened.
        let err = Document::parse("a = 1\nx = [\n  0.1, 0.2,").unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("line 2"), "{msg}");
        assert!(msg.contains("never closed"), "{msg}");
        // Bad items inside a multi-line array still fail.
        assert!(Document::parse("x = [\n0.1,\noops,\n]").is_err());
        // Double trailing commas are not tolerated even multi-line.
        assert!(Document::parse("x = [\n0.1,,\n]").is_err());
    }

    #[test]
    fn unknown_key_detection() {
        let d = Document::parse("[a]\nx = 1\ny = 2").unwrap();
        let unknown = d.unknown_keys(&["a.x"]);
        assert_eq!(unknown, vec!["a.y"]);
    }

    #[test]
    fn type_mismatches_yield_none() {
        let d = Document::parse("x = 5").unwrap();
        assert!(d.get("x").unwrap().as_bool().is_none());
        assert!(d.get("x").unwrap().as_str().is_none());
        assert_eq!(d.get("x").unwrap().as_f64(), Some(5.0));
    }
}
