//! Determinism guarantees around the batched decision engine:
//!
//! * same `AppConfig.seed` → bit-identical `Decision` stream through the
//!   coordinator, run twice;
//! * the coordinator's batched path reproduces the single-decision
//!   operator path **exactly** (same seed, same order), regardless of
//!   how the dynamic batcher happened to slice the stream into batches.
//!
//! This is the guard on the tentpole rewire: if the word-parallel
//! engines ever drift from the single-path bit algebra or RNG draw
//! order, these tests fail on the first diverging decision.

use std::time::Duration;

use bayes_mem::bayes::{FusionOperator, InferenceOperator};
use bayes_mem::config::AppConfig;
use bayes_mem::coordinator::{Coordinator, Decision, DecisionKind};
use bayes_mem::stochastic::SneBank;
use bayes_mem::util::Rng;

/// One worker so the worker-bank decision order equals submission order.
fn single_worker_config(seed: u64) -> AppConfig {
    let mut cfg = AppConfig::default();
    cfg.seed = seed;
    cfg.coordinator.workers = 1;
    cfg
}

fn inference_stream(n: usize, seed: u64) -> Vec<DecisionKind> {
    let mut rng = Rng::seeded(seed);
    (0..n)
        .map(|_| DecisionKind::Inference {
            prior: rng.range_f64(0.1, 0.9),
            likelihood: rng.range_f64(0.5, 0.95),
            likelihood_not: rng.range_f64(0.05, 0.5),
        })
        .collect()
}

fn fusion_stream(n: usize, seed: u64) -> Vec<DecisionKind> {
    let mut rng = Rng::seeded(seed);
    (0..n)
        .map(|_| DecisionKind::Fusion {
            posteriors: vec![rng.range_f64(0.2, 0.95), rng.range_f64(0.2, 0.95)],
        })
        .collect()
}

/// Submit the whole stream up-front (so the batcher forms real batches)
/// and collect the decisions in submission order.
fn serve(cfg: &AppConfig, kinds: &[DecisionKind]) -> Vec<Decision> {
    let coord = Coordinator::start(cfg).unwrap();
    let handle = coord.handle();
    let pending: Vec<_> = kinds.iter().map(|k| handle.submit(k.clone()).unwrap()).collect();
    let decisions: Vec<Decision> = pending
        .into_iter()
        .map(|p| p.wait_timeout(Duration::from_secs(30)).unwrap())
        .collect();
    coord.shutdown();
    decisions
}

#[test]
fn same_seed_gives_bit_identical_decision_stream() {
    let kinds = inference_stream(64, 11);
    let cfg = single_worker_config(2024);
    let first = serve(&cfg, &kinds);
    let second = serve(&cfg, &kinds);
    assert_eq!(first.len(), second.len());
    for (i, (a, b)) in first.iter().zip(&second).enumerate() {
        // f64 equality on purpose: the streams must be bit-identical.
        assert_eq!(a.posterior, b.posterior, "decision {i} diverged across runs");
        assert_eq!(a.exact, b.exact);
    }
}

#[test]
fn coordinator_batched_path_matches_single_path_inference_bitwise() {
    let kinds = inference_stream(64, 12);
    let cfg = single_worker_config(777);
    let served = serve(&cfg, &kinds);

    // The lone worker's bank is seeded `config.seed ^ (0 << 32)`; replay
    // the exact stream through the single-decision operator on an
    // identically-seeded bank.
    let mut bank = SneBank::new(cfg.sne.clone(), cfg.seed).unwrap();
    let op = InferenceOperator::default();
    for (i, (kind, decision)) in kinds.iter().zip(&served).enumerate() {
        let DecisionKind::Inference { prior, likelihood, likelihood_not } = kind else {
            unreachable!()
        };
        let single = op.try_infer(&mut bank, *prior, *likelihood, *likelihood_not).unwrap();
        assert_eq!(
            decision.posterior, single.posterior,
            "decision {i}: batched coordinator path diverged from single path"
        );
    }
}

#[test]
fn coordinator_batched_path_matches_single_path_fusion_bitwise() {
    let kinds = fusion_stream(48, 13);
    let cfg = single_worker_config(31337);
    let served = serve(&cfg, &kinds);

    let mut bank = SneBank::new(cfg.sne.clone(), cfg.seed).unwrap();
    let op = FusionOperator::default();
    for (i, (kind, decision)) in kinds.iter().zip(&served).enumerate() {
        let DecisionKind::Fusion { posteriors } = kind else { unreachable!() };
        let single = op.fuse(&mut bank, posteriors).unwrap();
        assert_eq!(
            decision.posterior, single.fused,
            "decision {i}: batched coordinator path diverged from single path"
        );
    }
}
