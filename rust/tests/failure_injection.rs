//! Failure injection: worn-out devices, poisoned artifacts, deadline
//! misses, malformed configs — the coordinator must degrade loudly and
//! predictably, never silently.

use std::io::Write;
use std::time::Duration;

use bayes_mem::config::{AppConfig, Backend};
use bayes_mem::coordinator::{Coordinator, DecisionKind};
use bayes_mem::device::{DeviceParams, WearPolicy};
use bayes_mem::runtime::Runtime;
use bayes_mem::stochastic::{SneBank, SneConfig};
use bayes_mem::Error;

fn inference_kind() -> DecisionKind {
    DecisionKind::Inference { prior: 0.57, likelihood: 0.77, likelihood_not: 0.655 }
}

/// Wear-out with `Fail` policy surfaces `DeviceWorn` through the serving
/// path instead of silently producing garbage.
#[test]
fn worn_bank_fails_requests_with_device_error() {
    let mut cfg = AppConfig::default();
    cfg.sne.params = DeviceParams { endurance_cycles: 60, ..Default::default() };
    cfg.sne.n_snes = 1;
    cfg.sne.wear_policy = WearPolicy::Fail;
    cfg.coordinator.workers = 1;
    cfg.coordinator.max_batch = 1;
    let coord = Coordinator::start(&cfg).unwrap();
    let handle = coord.handle();
    // Burn through the single device; eventually every response is a
    // DeviceWorn error (100-bit encodes at ~57 % switch ~57 cycles each).
    let mut saw_worn = false;
    for _ in 0..40 {
        match handle.decide(inference_kind()) {
            Ok(_) => {}
            Err(Error::DeviceWorn { .. }) => {
                saw_worn = true;
                break;
            }
            Err(e) => panic!("unexpected error: {e}"),
        }
    }
    assert!(saw_worn, "wear-out never surfaced");
    assert!(handle.metrics().snapshot().failed > 0);
    coord.shutdown();
}

/// Rotate policy keeps serving through wear by mapping in spares, then
/// fails once spares are exhausted.
#[test]
fn rotate_policy_extends_service_life() {
    let params = DeviceParams { endurance_cycles: 60, ..Default::default() };
    let cfg = SneConfig {
        n_bits: 100,
        n_snes: 2,
        params,
        wear_policy: WearPolicy::Rotate,
    };
    let mut bank = SneBank::new(cfg, 5).unwrap();
    let mut successes = 0;
    loop {
        match bank.encode(0.9) {
            Ok(_) => successes += 1,
            Err(Error::DeviceWorn { .. }) => break,
            Err(e) => panic!("unexpected {e}"),
        }
        assert!(successes < 1000, "never wore out");
    }
    // 2 active + 2 spares, each lasting ~1 encode at p=0.9/100 bits ≥ 60
    // cycles: at least 4 encodes must have succeeded.
    assert!(successes >= 4, "only {successes} encodes before failure");
}

/// A corrupted HLO artifact fails at load, with the entrypoint named.
#[test]
fn poisoned_artifact_fails_loudly() {
    let dir = tempdir();
    std::fs::create_dir_all(&dir).unwrap();
    let mut manifest = std::fs::File::create(dir.join("manifest.toml")).unwrap();
    writeln!(
        manifest,
        "[broken]\nfile = \"broken.hlo.txt\"\ninputs = 1\ninput0 = \"2,2\"\n"
    )
    .unwrap();
    std::fs::write(dir.join("broken.hlo.txt"), "HloModule utter garbage ((").unwrap();
    let err = match Runtime::load_dir(&dir) {
        Err(e) => e,
        Ok(_) => panic!("poisoned artifact compiled successfully"),
    };
    let msg = err.to_string();
    assert!(msg.contains("broken"), "unhelpful error: {msg}");
    std::fs::remove_dir_all(&dir).ok();
}

/// Missing artifacts directory on the PJRT backend: the coordinator still
/// starts (workers build lazily) but every decision errors.
#[test]
fn missing_artifacts_surface_as_request_errors() {
    let mut cfg = AppConfig::default();
    cfg.coordinator.backend = Backend::Pjrt;
    cfg.coordinator.workers = 1;
    cfg.artifacts_dir = tempdir(); // does not exist
    let coord = Coordinator::start(&cfg).unwrap();
    let handle = coord.handle();
    let err = handle
        .submit(inference_kind())
        .unwrap()
        .wait_timeout(Duration::from_secs(10))
        .unwrap_err();
    assert!(matches!(err, Error::Coordinator(_)), "got {err}");
    coord.shutdown();
}

/// Deadlines: a request with an impossible deadline is answered with
/// `Error::Deadline`, and counted as failed, not completed.
#[test]
fn impossible_deadline_reported() {
    let cfg = AppConfig::default();
    let coord = Coordinator::start(&cfg).unwrap();
    let handle = coord.handle();
    let p = handle
        .submit_with_deadline(inference_kind(), Some(Duration::from_nanos(1)))
        .unwrap();
    assert!(matches!(
        p.wait_timeout(Duration::from_secs(10)).unwrap_err(),
        Error::Deadline(_)
    ));
    let snap = handle.metrics().snapshot();
    assert_eq!(snap.failed, 1);
    // The miss also lands in its dedicated counter (it used to vanish
    // into the generic `failed`).
    assert_eq!(snap.deadline_missed, 1);
    assert_eq!(snap.completed, 0);
    coord.shutdown();
}

/// Config files with bad values are rejected before any thread spawns.
#[test]
fn bad_config_rejected_at_startup() {
    let mut cfg = AppConfig::default();
    cfg.coordinator.workers = 0;
    assert!(Coordinator::start(&cfg).is_err());
    let mut cfg = AppConfig::default();
    cfg.sne.n_bits = 0;
    assert!(Coordinator::start(&cfg).is_err());
}

fn tempdir() -> std::path::PathBuf {
    let mut p = std::env::temp_dir();
    p.push(format!(
        "bayes-mem-test-{}-{:?}",
        std::process::id(),
        std::thread::current().id()
    ));
    p
}
