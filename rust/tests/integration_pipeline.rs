//! End-to-end integration: artifacts → PJRT runtime → coordinator →
//! scene workload, plus native-vs-PJRT parity checks.
//!
//! PJRT-dependent tests no-op (pass vacuously) when `make artifacts` has
//! not been run, so a fresh checkout still gets a green `cargo test`.

use std::path::{Path, PathBuf};
use std::time::Duration;

use bayes_mem::bayes::{exact_fusion, FusionOperator, InferenceOperator};
use bayes_mem::config::{AppConfig, Backend};
use bayes_mem::coordinator::{Coordinator, DecisionKind};
use bayes_mem::runtime::Runtime;
use bayes_mem::scene::{
    detector_logits, fusion_input, DetectorModel, Modality, SceneGenerator, VideoWorkload,
};
use bayes_mem::stochastic::{SneBank, SneConfig};
use bayes_mem::util::stats::mean;
use bayes_mem::util::Rng;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.toml").exists().then_some(dir)
}

/// The detector head compiled into the AOT artifact must equal the native
/// Rust implementation (same published weights).
#[test]
fn detector_artifact_matches_native_model() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load_subset(&dir, &["detector_b64"]).unwrap();
    let mut gen = SceneGenerator::new(5);
    let rgb = DetectorModel::new(Modality::Rgb);
    let th = DetectorModel::new(Modality::Thermal);

    // Build a 64-row feature batch from real scene obstacles.
    let mut feats = Vec::with_capacity(64 * 6);
    let mut native = Vec::with_capacity(64 * 2);
    'outer: loop {
        let frame = gen.next_frame();
        for o in &frame.obstacles {
            let f = o.features(frame.visibility);
            feats.extend(f.iter().map(|&x| x as f32));
            native.push(rgb.confidence(o, frame.visibility));
            native.push(th.confidence(o, frame.visibility));
            if native.len() == 128 {
                break 'outer;
            }
        }
    }
    let out = rt.get("detector_b64").unwrap().run_f32(&[&feats]).unwrap();
    assert_eq!(out.len(), 128);
    for (i, (&got, &want)) in out.iter().zip(&native).enumerate() {
        assert!(
            (got as f64 - want).abs() < 1e-5,
            "row {i}: artifact {got} vs native {want}"
        );
    }
    // Belt & braces: the weights the artifact was built from.
    let (w, b) = detector_logits(Modality::Rgb);
    assert_eq!(w[1], 3.2);
    assert_eq!(b, -2.6);
}

/// The AOT stochastic-fusion kernel and the native bit-parallel simulator
/// must agree with closed-form Bayes (and hence each other) in mean.
#[test]
fn pjrt_and_native_fusion_agree_in_distribution() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load_subset(&dir, &["fusion_b16_m2_n256"]).unwrap();
    let mut rng = Rng::seeded(11);
    let cases = [(0.8f64, 0.7f64), (0.6, 0.9), (0.55, 0.55)];
    let mut bank = SneBank::new(SneConfig { n_bits: 256, ..Default::default() }, 12).unwrap();
    let fus = FusionOperator::default();
    for &(p1, p2) in &cases {
        let probs: Vec<f32> = (0..16).flat_map(|_| [p1 as f32, p2 as f32]).collect();
        let mut pjrt_samples = Vec::new();
        for _ in 0..8 {
            pjrt_samples
                .extend(rt.fusion("fusion_b16_m2_n256", &probs, &mut rng).unwrap().iter().map(|&x| x as f64));
        }
        let native_samples: Vec<f64> =
            (0..64).map(|_| fus.fuse2(&mut bank, p1, p2).unwrap().fused).collect();
        let exact = exact_fusion(p1, p2);
        let pjrt_mean = mean(&pjrt_samples);
        let native_mean = mean(&native_samples);
        assert!((pjrt_mean - exact).abs() < 0.03, "pjrt {pjrt_mean} vs exact {exact}");
        assert!((native_mean - exact).abs() < 0.03, "native {native_mean} vs exact {exact}");
        assert!((pjrt_mean - native_mean).abs() < 0.05);
    }
}

/// Full serving path on the PJRT backend: scene → coordinator → decisions.
#[test]
fn pjrt_coordinator_serves_scene_workload() {
    let Some(dir) = artifacts_dir() else { return };
    let mut cfg = AppConfig::default();
    cfg.coordinator.backend = Backend::Pjrt;
    cfg.coordinator.workers = 1;
    cfg.artifacts_dir = dir;
    let coord = Coordinator::start(&cfg).unwrap();
    let handle = coord.handle();
    let mut wl = VideoWorkload::new(21);
    let mut served = 0;
    for _ in 0..10 {
        let det = wl.next_detections();
        let pending: Vec<_> = det
            .confidences
            .iter()
            .map(|&(r, t)| {
                handle
                    .submit(DecisionKind::Fusion {
                        posteriors: vec![fusion_input(r), fusion_input(t)],
                    })
                    .unwrap()
            })
            .collect();
        for p in pending {
            let d = p.wait_timeout(Duration::from_secs(30)).unwrap();
            assert!((0.0..=1.0).contains(&d.posterior));
            served += 1;
        }
    }
    assert!(served >= 10);
    assert_eq!(handle.metrics().snapshot().completed, served);
    coord.shutdown();
}

/// Native end-to-end: inference + fusion accuracy through the coordinator
/// at paper precision, across a mixed workload.
#[test]
fn native_end_to_end_accuracy() {
    let mut cfg = AppConfig::default();
    cfg.sne.n_bits = 1_000;
    let coord = Coordinator::start(&cfg).unwrap();
    let handle = coord.handle();
    let mut rng = Rng::seeded(31);
    let mut errors = Vec::new();
    let pending: Vec<_> = (0..200)
        .map(|i| {
            let kind = if i % 2 == 0 {
                DecisionKind::Inference {
                    prior: rng.range_f64(0.2, 0.8),
                    likelihood: rng.range_f64(0.5, 0.95),
                    likelihood_not: rng.range_f64(0.05, 0.5),
                }
            } else {
                DecisionKind::Fusion {
                    posteriors: vec![rng.range_f64(0.3, 0.9), rng.range_f64(0.3, 0.9)],
                }
            };
            handle.submit(kind).unwrap()
        })
        .collect();
    for p in pending {
        let d = p.wait_timeout(Duration::from_secs(30)).unwrap();
        errors.push(d.abs_error());
    }
    let mae = mean(&errors);
    assert!(mae < 0.04, "1000-bit MAE {mae}");
    coord.shutdown();
}

/// Direct operators and the coordinator path must produce the same
/// statistics for the Fig. 3b scenario.
#[test]
fn coordinator_matches_direct_operator_statistics() {
    let cfg = AppConfig::default();
    let coord = Coordinator::start(&cfg).unwrap();
    let handle = coord.handle();
    let via_coord: Vec<f64> = (0..300)
        .map(|_| {
            handle
                .decide(DecisionKind::Inference {
                    prior: 0.57,
                    likelihood: 0.77,
                    likelihood_not: 0.655,
                })
                .unwrap()
                .posterior
        })
        .collect();
    coord.shutdown();
    let mut bank = SneBank::seeded(99);
    let op = InferenceOperator::default();
    let direct: Vec<f64> = (0..300).map(|_| op.fig3b(&mut bank).posterior).collect();
    assert!((mean(&via_coord) - mean(&direct)).abs() < 0.03);
    assert!((mean(&via_coord) - 0.609).abs() < 0.03);
}
