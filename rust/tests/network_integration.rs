//! ISSUE-2 acceptance suite for the Bayesian-network compiler:
//!
//! * all three Fig. S8 topologies plus ≥10 random 5-node DAGs agree
//!   with full-joint exact enumeration within 0.02 mean absolute error
//!   at 2¹⁴-bit streams;
//! * the on-disk spec format (`specs/intersection.toml`) parses,
//!   validates, compiles and evaluates — so the format cannot rot;
//! * `DecisionKind::Network` requests flow submit → batcher → worker →
//!   reply with backpressure and per-kind metrics.

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

use bayes_mem::bayes::{InferenceOperator, OneParentTwoChild, TwoParentOneChild};
use bayes_mem::config::AppConfig;
use bayes_mem::coordinator::{Coordinator, DecisionKind, KindTag};
use bayes_mem::network::{
    compile_query, exact_posterior_by_name, BayesNet, NetlistEvaluator,
};
use bayes_mem::stochastic::{SneBank, SneConfig};
use bayes_mem::util::Rng;
use bayes_mem::Error;

const N_BITS: usize = 1 << 14;

fn bank(n_bits: usize, seed: u64) -> SneBank {
    SneBank::new(SneConfig { n_bits, ..Default::default() }, seed).unwrap()
}

fn spec_path() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("../specs/intersection.toml")
}

fn intersection() -> BayesNet {
    let mut net = BayesNet::named("intersection");
    net.add_root("fog", 0.15).unwrap();
    net.add_root("occlusion", 0.25).unwrap();
    net.add_node("visibility", &["fog"], &[0.9, 0.3]).unwrap();
    net.add_node("detection", &["visibility", "occlusion"], &[0.55, 0.2, 0.95, 0.5])
        .unwrap();
    net.add_node("alarm", &["detection"], &[0.05, 0.98]).unwrap();
    net
}

/// Acceptance: the three Fig. S8 topologies, compiled through the
/// netlist path, stay within 0.02 MAE of exact enumeration at 2^14 bits.
#[test]
fn fig_s8_topologies_match_exact_enumeration_at_2_14_bits() {
    let mut errs = Vec::new();

    // A → B (the Eq.-1 shape) through the generic compiler.
    let mut chain = BayesNet::named("one_parent_one_child");
    chain.add_root("a", 0.57).unwrap();
    chain.add_node("b", &["a"], &[0.655, 0.77]).unwrap();
    let nl = compile_query(&chain, "a", &[("b", true)]).unwrap();
    let r = NetlistEvaluator::new().evaluate(&mut bank(N_BITS, 101), &nl).unwrap();
    let (exact, _) = exact_posterior_by_name(&chain, "a", &[("b", true)]).unwrap();
    // Cross-check the generic exact engine against the Eq.-1 closed form.
    assert!((exact - bayes_mem::bayes::exact_posterior(0.57, 0.77, 0.655)).abs() < 1e-12);
    errs.push((r.posterior - exact).abs());

    // A₁ → B ← A₂.
    let two = TwoParentOneChild {
        p_a1: 0.6,
        p_a2: 0.4,
        p_b_given: [[0.1, 0.5], [0.6, 0.9]],
    };
    let r = two.evaluate(&mut bank(N_BITS, 102)).unwrap();
    errs.push(r.abs_error());

    // B₁ ← A → B₂.
    let one = OneParentTwoChild { p_a: 0.57, p_b1: (0.8, 0.3), p_b2: (0.7, 0.4) };
    let r = one.evaluate(&mut bank(N_BITS, 103)).unwrap();
    errs.push(r.abs_error());

    let mae = errs.iter().sum::<f64>() / errs.len() as f64;
    assert!(mae < 0.02, "Fig. S8 MAE {mae:.4} at 2^14 bits (errs {errs:?})");
    for (i, e) in errs.iter().enumerate() {
        assert!(*e < 0.05, "topology {i} err {e:.4}");
    }
}

/// Acceptance: ≥10 random 5-node DAGs within 0.02 MAE at 2^14 bits.
#[test]
fn random_5node_dags_match_exact_enumeration_at_2_14_bits() {
    let mut rng = Rng::seeded(0xDA65);
    let mut errs = Vec::new();
    let mut eval = NetlistEvaluator::new();
    for case in 0..12 {
        // Random DAG over 5 nodes, ≤2 parents, CPTs in [0.2, 0.8] so
        // the evidence keeps healthy probability mass.
        let mut net = BayesNet::named("rand5");
        for i in 0..5usize {
            let name = format!("n{i}");
            let mut parent_names: Vec<String> = Vec::new();
            for j in 0..i {
                if rng.bernoulli(0.45) {
                    parent_names.push(format!("n{j}"));
                }
            }
            parent_names.truncate(2);
            let parent_refs: Vec<&str> =
                parent_names.iter().map(String::as_str).collect();
            let cpt: Vec<f64> = (0..(1usize << parent_refs.len()))
                .map(|_| 0.2 + 0.6 * rng.f64())
                .collect();
            net.add_node(&name, &parent_refs, &cpt).unwrap();
        }
        // Single-node evidence keeps P(E) ≥ 0.2 (CPTs are in [0.2, 0.8])
        // so the CORDIV variance stays far inside the 0.02 MAE budget;
        // multi-node and negative evidence are covered by the property
        // and unit suites.
        let evidence = [("n4", true)];
        let nl = compile_query(&net, "n0", &evidence).unwrap();
        let (exact, p_ev) = exact_posterior_by_name(&net, "n0", &evidence).unwrap();
        assert!(p_ev > 0.19, "case {case}: P(evidence) {p_ev}");
        let mut b = bank(N_BITS, 9000 + case);
        let r = eval.evaluate(&mut b, &nl).unwrap();
        let err = (r.posterior - exact).abs();
        assert!(err < 0.06, "case {case}: err {err:.4} ({} vs {exact})", r.posterior);
        errs.push(err);
    }
    assert!(errs.len() >= 10);
    let mae = errs.iter().sum::<f64>() / errs.len() as f64;
    assert!(mae < 0.02, "random-DAG MAE {mae:.4} at 2^14 bits (errs {errs:?})");
}

/// The on-disk spec format: parse, validate, compile, evaluate, and stay
/// in lockstep with the generic exact engine and the Eq.-1 operator.
#[test]
fn on_disk_spec_parses_validates_and_evaluates() {
    let net = BayesNet::load(&spec_path()).unwrap();
    assert_eq!(net.name(), "intersection");
    assert_eq!(net.len(), 5);
    net.validate().unwrap();
    // The file and the in-code builder network describe the same joint:
    // identical exact posteriors on a probe query.
    let built = intersection();
    let probes: [(&str, &[(&str, bool)]); 3] = [
        ("occlusion", &[("detection", false), ("visibility", true)]),
        ("fog", &[("alarm", true)]),
        ("detection", &[]),
    ];
    for (query, evidence) in probes {
        let (from_file, ev_file) = exact_posterior_by_name(&net, query, evidence).unwrap();
        let (from_code, ev_code) = exact_posterior_by_name(&built, query, evidence).unwrap();
        assert!((from_file - from_code).abs() < 1e-12, "{query} drifted");
        assert!((ev_file - ev_code).abs() < 1e-12);
    }
    // And it evaluates on the stochastic path within MC noise.
    let evidence = [("alarm", true)];
    let nl = compile_query(&net, "fog", &evidence).unwrap();
    let (exact, p_ev) = exact_posterior_by_name(&net, "fog", &evidence).unwrap();
    assert!(p_ev > 0.3);
    let r = NetlistEvaluator::new().evaluate(&mut bank(N_BITS, 77), &nl).unwrap();
    assert!((r.posterior - exact).abs() < 0.05, "{} vs {exact}", r.posterior);
    assert!((r.marginal - p_ev).abs() < 0.05);
}

/// Acceptance: Network requests flow submit → batcher → worker → reply,
/// with per-kind metrics observable after a mixed load.
#[test]
fn coordinator_serves_mixed_load_with_per_kind_metrics() {
    let mut cfg = AppConfig::default();
    cfg.coordinator.workers = 2;
    cfg.coordinator.max_batch = 8;
    cfg.coordinator.max_wait = Duration::from_micros(200);
    let coord = Coordinator::start(&cfg).unwrap();
    let h = coord.handle();
    let net = Arc::new(intersection());
    let mut pending = Vec::new();
    for i in 0..48 {
        let kind = match i % 3 {
            0 => DecisionKind::Inference {
                prior: 0.57,
                likelihood: 0.77,
                likelihood_not: 0.655,
            },
            1 => DecisionKind::Fusion { posteriors: vec![0.8, 0.7] },
            _ => DecisionKind::Network {
                net: Arc::clone(&net),
                query: "occlusion".into(),
                evidence: vec![("detection".into(), false), ("visibility".into(), true)],
            },
        };
        pending.push(h.submit(kind).unwrap());
    }
    for p in pending {
        let d = p.wait_timeout(Duration::from_secs(10)).unwrap();
        assert!((0.0..=1.0).contains(&d.posterior));
        assert!(d.exact.is_finite());
    }
    let snap = h.metrics().snapshot();
    assert_eq!(snap.completed, 48);
    assert_eq!(snap.completed_for(KindTag::Inference), 16);
    assert_eq!(snap.completed_for(KindTag::Fusion), 16);
    assert_eq!(snap.completed_for(KindTag::Network), 16);
    assert_eq!(
        snap.completed_by_kind.iter().sum::<u64>(),
        snap.completed,
        "per-kind counters must partition completions"
    );
    coord.shutdown();
}

/// Backpressure: network requests shed at admission when the queue is
/// full, and every accepted request still completes.
#[test]
fn network_requests_respect_backpressure() {
    let mut cfg = AppConfig::default();
    cfg.coordinator.workers = 1;
    cfg.coordinator.max_batch = 4;
    cfg.coordinator.max_wait = Duration::from_millis(200); // slow drain
    cfg.coordinator.queue_capacity = 4;
    let coord = Coordinator::start(&cfg).unwrap();
    let h = coord.handle();
    let net = Arc::new(intersection());
    let mut accepted = Vec::new();
    let mut rejections = 0;
    for _ in 0..5_000 {
        let kind = DecisionKind::Network {
            net: Arc::clone(&net),
            query: "fog".into(),
            evidence: vec![("alarm".into(), true)],
        };
        match h.submit(kind) {
            Ok(p) => accepted.push(p),
            Err(Error::Coordinator(_)) => rejections += 1,
            Err(e) => panic!("unexpected error {e}"),
        }
    }
    assert!(rejections > 0, "queue never filled");
    for p in accepted {
        let d = p.wait_timeout(Duration::from_secs(30)).unwrap();
        assert!((0.0..=1.0).contains(&d.posterior));
    }
    let snap = h.metrics().snapshot();
    assert_eq!(snap.rejected, rejections);
    coord.shutdown();
}

/// Invalid network requests are rejected at admission with typed errors
/// and never reach a worker.
#[test]
fn invalid_network_requests_rejected_at_admission() {
    let coord = Coordinator::start(&AppConfig::default()).unwrap();
    let h = coord.handle();
    let net = Arc::new(intersection());
    let err = h
        .submit(DecisionKind::Network {
            net: Arc::clone(&net),
            query: "nope".into(),
            evidence: vec![],
        })
        .unwrap_err();
    assert!(matches!(err, Error::Network(_)));
    let err = h
        .submit(DecisionKind::Network {
            net,
            query: "fog".into(),
            evidence: vec![("alarm".into(), true), ("alarm".into(), true)],
        })
        .unwrap_err();
    assert!(matches!(err, Error::Network(_)));
    assert_eq!(h.metrics().snapshot().rejected, 2);
    coord.shutdown();
}

/// Same seed + same request order ⇒ bit-identical network decisions
/// through the whole coordinator (single worker, batch-of-one).
#[test]
fn network_decisions_are_deterministic_via_coordinator() {
    let run = || -> Vec<f64> {
        let mut cfg = AppConfig::default();
        cfg.coordinator.workers = 1;
        cfg.coordinator.max_batch = 1;
        let coord = Coordinator::start(&cfg).unwrap();
        let h = coord.handle();
        let net = Arc::new(intersection());
        let out: Vec<f64> = (0..6)
            .map(|i| {
                let kind = DecisionKind::Network {
                    net: Arc::clone(&net),
                    query: "occlusion".into(),
                    evidence: vec![("detection".into(), i % 2 == 0)],
                };
                h.decide(kind).unwrap().posterior
            })
            .collect();
        coord.shutdown();
        out
    };
    assert_eq!(run(), run());
}

/// The compiled coordinator path and a hand-driven evaluator on the same
/// seeded bank agree bit-for-bit (submit → worker == direct evaluate).
#[test]
fn coordinator_network_path_matches_direct_evaluation() {
    let mut cfg = AppConfig::default();
    cfg.coordinator.workers = 1;
    cfg.coordinator.max_batch = 1;
    let coord = Coordinator::start(&cfg).unwrap();
    let h = coord.handle();
    let net = Arc::new(intersection());
    let kind = DecisionKind::Network {
        net: Arc::clone(&net),
        query: "fog".into(),
        evidence: vec![("alarm".into(), true)],
    };
    let via_coordinator = h.decide(kind).unwrap().posterior;
    coord.shutdown();

    // Worker 0 builds its bank from config.seed ^ (0 << 32) = seed.
    let cfg = AppConfig::default();
    let mut direct_bank = SneBank::new(cfg.sne.clone(), cfg.seed).unwrap();
    let nl = compile_query(&net, "fog", &[("alarm", true)]).unwrap();
    let direct = NetlistEvaluator::new().evaluate(&mut direct_bank, &nl).unwrap();
    assert_eq!(via_coordinator, direct.posterior);
}

/// The one-parent-one-child chain through the coordinator's network path
/// is bit-identical to the Eq.-1 inference operator on the same bank
/// seed — the serving layer's two routes to the same circuit agree.
#[test]
fn network_chain_equals_inference_operator_bitwise() {
    let cfg = AppConfig::default();
    let (pa, pb1, pb0) = (0.57, 0.77, 0.655);
    let mut net = BayesNet::named("chain");
    net.add_root("a", pa).unwrap();
    net.add_node_rows("b", &["a"], &[(1, pb1), (0, pb0)]).unwrap();
    let nl = compile_query(&net, "a", &[("b", true)]).unwrap();
    let mut net_bank = SneBank::new(cfg.sne.clone(), 7).unwrap();
    let r = NetlistEvaluator::new().evaluate(&mut net_bank, &nl).unwrap();
    let mut op_bank = SneBank::new(cfg.sne.clone(), 7).unwrap();
    let op = InferenceOperator::default().try_infer(&mut op_bank, pa, pb1, pb0).unwrap();
    assert_eq!(r.posterior, op.posterior);
    assert_eq!(r.marginal, op.marginal);
}
