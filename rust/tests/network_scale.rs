//! Scene-scale acceptance suite — the "lift the 20-node cap" PR:
//!
//! * the variable-elimination exact engine agrees with full-joint
//!   enumeration to ≤1e-12 on random ≤20-node DAGs (including
//!   deterministic CPT rows and degenerate evidence);
//! * `specs/scene100.toml` (111 nodes, a 12-parent noisy-OR alarm with a
//!   4096-row multi-line CPT) loads, validates, compiles, optimizes
//!   (≥25 % gate reduction) and serves through a prepared plan within
//!   0.02 MAE of VE at 2¹⁴-bit streams;
//! * the optimizer preserves posteriors on random fodder DAGs rich in
//!   duplicate/deterministic rows (optimized vs raw within combined
//!   Wilson half-widths);
//! * log-domain streams decide a 31-deep fully-observed chain whose
//!   evidence mass (≈1e-8) starves the linear CORDIV denominator.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use bayes_mem::coordinator::{DecisionParams, PlanSpec, PreparedPlan};
use bayes_mem::network::{
    self, compile_query, evaluate_query_in_domain, exact_posterior_by_name,
    full_joint_posterior_by_name, optimize, BayesNet, NetlistEvaluator, StopPolicy,
    StreamDomain, MAX_COMPILED_COST,
};
use bayes_mem::stochastic::{SneBank, SneConfig};
use bayes_mem::util::Rng;
use bayes_mem::Error;

const N_BITS: usize = 1 << 14;

fn bank(n_bits: usize, seed: u64) -> SneBank {
    SneBank::new(SneConfig { n_bits, ..Default::default() }, seed).unwrap()
}

fn scene100_path() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("../specs/scene100.toml")
}

fn scene100() -> BayesNet {
    BayesNet::load(&scene100_path()).expect("specs/scene100.toml parses")
}

/// Random DAG over `n` nodes, ≤3 parents each, CPT entries drawn from a
/// palette rich in deterministic (0/1) and duplicate values — exactly
/// the structure the optimizer folds and shares.
fn random_net(rng: &mut Rng, n: usize, deterministic_rows: bool) -> BayesNet {
    let mut net = BayesNet::named("rand");
    for i in 0..n {
        let name = format!("n{i:02}");
        let mut parent_names: Vec<String> = Vec::new();
        for j in 0..i {
            if rng.bernoulli(2.0 / (i as f64 + 1.0)) {
                parent_names.push(format!("n{j:02}"));
            }
        }
        parent_names.truncate(3);
        let parent_refs: Vec<&str> = parent_names.iter().map(String::as_str).collect();
        let rows = 1usize << parent_refs.len();
        let mut cpt = Vec::with_capacity(rows);
        for r in 0..rows {
            let p = if deterministic_rows && r > 0 && rng.bernoulli(0.25) {
                // Duplicate an earlier row: share-streams fodder.
                cpt[rng.below(r)]
            } else if deterministic_rows && rng.bernoulli(0.2) {
                // Deterministic row: fold-constants fodder.
                if rng.bernoulli(0.5) {
                    0.0
                } else {
                    1.0
                }
            } else {
                rng.range_f64(0.05, 0.95)
            };
            cpt.push(p);
        }
        net.add_node(&name, &parent_refs, &cpt).unwrap();
    }
    net
}

/// Satellite: variable elimination vs full-joint enumeration, ≤1e-12 on
/// both the posterior and the evidence mass, across random ≤20-node
/// nets with adversarial CPTs and evidence (incl. impossible evidence).
#[test]
fn variable_elimination_matches_full_joint_to_1e12() {
    let mut rng = Rng::seeded(0xE11E_5EED);
    let mut cases = 0;
    for round in 0..40 {
        let n = rng.range_usize(5, 13);
        let net = random_net(&mut rng, n, round % 2 == 0);
        let query = format!("n{:02}", rng.below(n));
        let mut evidence: Vec<(String, bool)> = Vec::new();
        for i in 0..n {
            let name = format!("n{i:02}");
            if name != query && rng.bernoulli(0.3) {
                evidence.push((name, rng.bernoulli(0.5)));
            }
        }
        evidence.truncate(3);
        let ev: Vec<(&str, bool)> = evidence.iter().map(|(s, v)| (s.as_str(), *v)).collect();
        let (ve_p, ve_ev) = exact_posterior_by_name(&net, &query, &ev).unwrap();
        let (fj_p, fj_ev) = full_joint_posterior_by_name(&net, &query, &ev).unwrap();
        assert!(
            (ve_p - fj_p).abs() <= 1e-12,
            "round {round}: posterior VE {ve_p} vs full joint {fj_p}"
        );
        assert!(
            (ve_ev - fj_ev).abs() <= 1e-12,
            "round {round}: P(ev) VE {ve_ev} vs full joint {fj_ev}"
        );
        cases += 1;
    }
    assert_eq!(cases, 40);
}

/// Tentpole: the 111-node scene spec loads through the multi-line-array
/// TOML path, validates under the raised caps, and fits the compiled
/// gate budget.
#[test]
fn scene100_loads_validates_and_fits_the_gate_budget() {
    let net = scene100();
    assert_eq!(net.name(), "scene100");
    assert_eq!(net.len(), 111);
    net.validate().unwrap();
    let alarm = &net.nodes()[net.node_index("alarm").unwrap()];
    assert_eq!(alarm.parents.len(), 12, "noisy-OR alarm has 12 parents");
    assert_eq!(alarm.cpt.len(), 4096, "4096-row CPT via multi-line arrays");
    let cost = network::compiled_cost(&net);
    assert!(
        cost < MAX_COMPILED_COST,
        "scene100 compiles to ~{cost} streams+gates, budget {MAX_COMPILED_COST}"
    );
}

/// Tentpole: the optimizer collapses the scene100 netlist — the
/// 12-parent noisy-OR's 4096 rows carry only 13 distinct probabilities,
/// so share-streams + CSE fold its MUX tree level by level. Acceptance
/// is ≥25 % gate reduction; the symmetric alarm makes it far larger.
#[test]
fn optimizer_reduces_scene100_gates_by_at_least_25_percent() {
    let net = scene100();
    let raw = compile_query(&net, "obj00_hazard", &[("alarm", true)]).unwrap();
    let (opt, stats) = optimize(&raw);
    assert!(
        stats.gate_reduction() >= 0.25,
        "gate reduction {:.3} below the 25% acceptance ({} -> {})",
        stats.gate_reduction(),
        stats.gates_before,
        stats.gates_after
    );
    // The symmetric-CPT collapse is dramatic, not marginal.
    assert!(
        stats.gates_after < 400,
        "expected the noisy-OR tree to collapse, still {} gates",
        stats.gates_after
    );
    assert!(stats.streams_after < stats.streams_before);
    // Per-pass accounting is exposed and consistent.
    assert!(stats.passes.iter().any(|p| p.name == "share-streams" && p.changed));
    assert!(stats.passes.iter().any(|p| p.name == "cse" && p.changed));
    assert_eq!(stats.passes.last().unwrap().name, "dead-gate-elim");
    assert_eq!(stats.gates_after, opt.ops().len());
    assert_eq!(stats.streams_after, opt.inputs().len());
}

/// Tentpole acceptance: scene100 served through a prepared plan stays
/// within 0.02 MAE of variable elimination at 2¹⁴-bit streams. The VE
/// references are additionally pinned against an independent Python
/// implementation of the same eliminator (1e-5), so a Rust-side VE bug
/// cannot silently re-baseline the stochastic check.
#[test]
fn scene100_serves_through_prepared_plans_within_mae() {
    let net = Arc::new(scene100());
    // (query, evidence, independently computed posterior, P(ev))
    let cases: [(&str, Vec<(&str, bool)>, f64, f64); 3] = [
        ("obj00_hazard", vec![("alarm", true)], 0.030857, 0.389093),
        ("fog", vec![("alarm", true), ("road_wet", true)], 0.120000, 0.100507),
        ("traction", vec![("alarm", true), ("night", true)], 0.857158, 0.108952),
    ];
    let mut errs = Vec::new();
    for (i, (query, evidence, py_posterior, py_ev)) in cases.iter().enumerate() {
        let (exact, p_ev) = exact_posterior_by_name(&net, query, evidence).unwrap();
        // 5e-5: immune to float summation-order differences between the
        // two eliminators, far below any real inference bug.
        assert!(
            (exact - py_posterior).abs() < 5e-5,
            "case {i}: Rust VE {exact} vs independent reference {py_posterior}"
        );
        assert!((p_ev - py_ev).abs() < 5e-5, "case {i}: P(ev) {p_ev} vs {py_ev}");

        let spec = PlanSpec::Network {
            net: Arc::clone(&net),
            query: (*query).into(),
            evidence: evidence.iter().map(|(n, v)| ((*n).into(), *v)).collect(),
        };
        let plan = PreparedPlan::compile(spec).unwrap();
        let stats = plan.opt_stats().expect("network plans carry optimizer stats");
        assert!(stats.gate_reduction() > 0.25, "case {i}: {:.3}", stats.gate_reduction());
        let baked = DecisionParams::Network { overrides: vec![] };
        assert!((plan.exact(&baked) - exact).abs() < 1e-12);

        let mut b = bank(N_BITS, 4200 + i as u64);
        let mut eval = NetlistEvaluator::new();
        let posterior = plan.decide_on(&mut b, &mut eval, &baked).unwrap();
        let err = (posterior - exact).abs();
        assert!(err < 0.05, "case {i}: served {posterior} vs exact {exact}");
        errs.push(err);
    }
    let mae = errs.iter().sum::<f64>() / errs.len() as f64;
    assert!(mae < 0.02, "scene100 MAE {mae:.4} at 2^14 bits (errs {errs:?})");
}

/// A thin-evidence branch of scene100 (P(ev) ≈ 0.045): still served,
/// with a proportionally looser stochastic bound.
#[test]
fn scene100_thin_evidence_query_stays_in_tolerance() {
    let net = scene100();
    let ev = [("obj05_seen", true), ("alarm", true)];
    let (exact, p_ev) = exact_posterior_by_name(&net, "obj05_present", &ev).unwrap();
    assert!((exact - 0.854772).abs() < 5e-5, "VE drifted: {exact}");
    assert!((p_ev - 0.044604).abs() < 5e-5, "P(ev) drifted: {p_ev}");
    let raw = compile_query(&net, "obj05_present", &ev).unwrap();
    let (opt, _) = optimize(&raw);
    let r = NetlistEvaluator::new()
        .evaluate_anytime(&mut bank(N_BITS, 4300), &opt, opt.inputs(), &StopPolicy::Never)
        .unwrap();
    // ~730 effective divisor hits: Wilson half-width ≈ 0.04.
    assert!(
        (r.posterior - exact).abs() < 3.0 * r.half_width.max(0.02),
        "{} vs {exact} (half-width {})",
        r.posterior,
        r.half_width
    );
}

/// Satellite property: the optimizer preserves posteriors. Random fodder
/// DAGs rich in duplicate and deterministic CPT rows, evaluated raw and
/// optimized on independently seeded banks at 2¹⁴ bits — the two
/// measurements must agree within their combined Wilson half-widths
/// (plus a small slack for the shared exact reference), and each must
/// sit within its own interval of the VE exact value.
#[test]
fn optimizer_preserves_posteriors_on_random_fodder_nets() {
    let mut rng = Rng::seeded(0x0F7F_5EED);
    let mut eval = NetlistEvaluator::new();
    let mut checked = 0;
    let mut round = 0;
    while checked < 12 {
        round += 1;
        assert!(round < 200, "could not find enough well-conditioned fodder nets");
        let n = rng.range_usize(5, 13);
        let net = random_net(&mut rng, n, true);
        let query = "n00";
        let last = format!("n{:02}", n - 1);
        let evidence = [(last.as_str(), true)];
        let (exact, p_ev) = exact_posterior_by_name(&net, query, &evidence).unwrap();
        if p_ev < 0.05 {
            continue; // starved CORDIV den ⇒ testing noise, not the optimizer
        }
        let raw = compile_query(&net, query, &evidence).unwrap();
        let (opt, stats) = optimize(&raw);
        let r_raw = eval
            .evaluate_anytime(
                &mut bank(N_BITS, 7000 + round),
                &raw,
                raw.inputs(),
                &StopPolicy::Never,
            )
            .unwrap();
        let r_opt = eval
            .evaluate_anytime(
                &mut bank(N_BITS, 9000 + round),
                &opt,
                opt.inputs(),
                &StopPolicy::Never,
            )
            .unwrap();
        let combined = r_raw.half_width + r_opt.half_width + 0.02;
        assert!(
            (r_raw.posterior - r_opt.posterior).abs() <= combined,
            "round {round} (reduction {:.2}): raw {} vs optimized {} exceeds \
             combined Wilson half-widths {combined:.4}",
            stats.gate_reduction(),
            r_raw.posterior,
            r_opt.posterior
        );
        for (label, r) in [("raw", &r_raw), ("optimized", &r_opt)] {
            assert!(
                (r.posterior - exact).abs() <= r.half_width + 0.03,
                "round {round}: {label} {} vs exact {exact} (half-width {})",
                r.posterior,
                r.half_width
            );
        }
        checked += 1;
    }
}

/// Tentpole: a 31-deep fully-observed chain. The linear stream encoding
/// underflows — P(evidence) ≈ 1e-8, so at 2¹⁴ bits the CORDIV
/// denominator essentially never fires — while the log-domain encoding
/// accumulates the same evidence additively and lands on the VE
/// posterior.
#[test]
fn log_domain_survives_a_30_deep_chain_where_linear_underflows() {
    let depth = 31;
    let mut net = BayesNet::named("deep-chain");
    net.add_root("c00", 0.5).unwrap();
    for i in 1..depth {
        let parent = format!("c{:02}", i - 1);
        net.add_node(&format!("c{i:02}"), &[parent.as_str()], &[0.3, 0.8]).unwrap();
    }
    let query = "c15";
    let evidence_owned: Vec<(String, bool)> = (0..depth)
        .filter(|&i| i != 15)
        .map(|i| (format!("c{i:02}"), i % 2 == 0))
        .collect();
    let ev: Vec<(&str, bool)> =
        evidence_owned.iter().map(|(n, v)| (n.as_str(), *v)).collect();

    // VE handles the 31-node net exactly (full joint cannot: 2^31).
    let (exact, p_ev) = exact_posterior_by_name(&net, query, &ev).unwrap();
    assert!(p_ev < 1e-7, "chain evidence mass should be tiny, got {p_ev}");
    assert!(
        network::full_joint_posterior_by_name(&net, query, &ev).is_err(),
        "full joint must refuse 31 nodes"
    );

    // Linear: the denominator density *is* P(ev) ≈ 1e-8 — at 2^14 bits
    // the measured evidence mass reads (essentially) zero.
    let lin =
        evaluate_query_in_domain(&mut bank(N_BITS, 31), &net, query, &ev, StreamDomain::Linear)
            .unwrap();
    assert!(
        lin.marginal < 1e-3,
        "linear evidence mass should starve, measured {}",
        lin.marginal
    );

    // Log-domain: additive accumulation at R = 64 recovers the posterior.
    let log = evaluate_query_in_domain(
        &mut bank(N_BITS, 31),
        &net,
        query,
        &ev,
        StreamDomain::Log { exchange_rate: 64 },
    )
    .unwrap();
    assert!(
        (log.posterior - exact).abs() < 0.02,
        "log-domain {} vs exact {exact}",
        log.posterior
    );
    // And its reconstructed evidence mass is the right order of
    // magnitude, where linear read ~0.
    assert!(log.marginal > 0.0 && (log.marginal.log2() - p_ev.log2()).abs() < 0.5);
}

/// Satellite: the raised caps thread through plan admission — an
/// in-cap scene-scale net is admitted, and a net past the compiled-gate
/// budget is rejected with the typed budget error.
#[test]
fn plan_admission_enforces_the_compiled_gate_budget() {
    // scene100 (111 nodes, ~9k compiled cost) is admitted.
    let ok = PlanSpec::Network {
        net: Arc::new(scene100()),
        query: "alarm".into(),
        evidence: vec![("fog".into(), true)],
    };
    PreparedPlan::compile(ok).unwrap();

    // 12 roots + 17 twelve-parent nodes ≈ 17·(2^13−1) compiled slots:
    // past the budget, rejected before any compilation work.
    let mut net = BayesNet::named("too-wide");
    let roots: Vec<String> = (0..12).map(|i| format!("r{i:02}")).collect();
    for r in &roots {
        net.add_root(r, 0.5).unwrap();
    }
    let parent_refs: Vec<&str> = roots.iter().map(String::as_str).collect();
    let cpt = vec![0.5; 1 << 12];
    for i in 0..17 {
        net.add_node(&format!("w{i:02}"), &parent_refs, &cpt).unwrap();
    }
    let bad = PlanSpec::Network {
        net: Arc::new(net),
        query: "w00".into(),
        evidence: vec![("r00".into(), true)],
    };
    let err = PreparedPlan::compile(bad).unwrap_err();
    match err {
        Error::Network(msg) => {
            assert!(msg.contains("compiled-gate budget"), "{msg}")
        }
        other => panic!("expected Error::Network, got {other}"),
    }
}
