//! Acceptance suite for the observability subsystem (ISSUE-7): stage
//! spans must decompose exactly to end-to-end latency, the quantile
//! histograms must populate for every stage, the exposition must carry
//! p50/p99/p999 for latency and each stage, and the Chrome trace dump
//! must be well-formed.

use std::time::Duration;

use bayes_mem::config::AppConfig;
use bayes_mem::coordinator::{Coordinator, DecisionParams, PlanSpec};
use bayes_mem::obs::{chrome_trace_json, Stage};
use bayes_mem::scene::{pipeline, PipelineConfig, ScenarioSpec};

/// Minimal structural JSON check (no serde in the offline build):
/// balanced braces/brackets outside strings and no bare NaN/Inf.
fn assert_jsonish(s: &str, what: &str) {
    let (mut brace, mut bracket) = (0i64, 0i64);
    let mut in_str = false;
    let mut esc = false;
    for c in s.chars() {
        if in_str {
            if esc {
                esc = false;
            } else if c == '\\' {
                esc = true;
            } else if c == '"' {
                in_str = false;
            }
            continue;
        }
        match c {
            '"' => in_str = true,
            '{' => brace += 1,
            '}' => brace -= 1,
            '[' => bracket += 1,
            ']' => bracket -= 1,
            _ => {}
        }
        assert!(brace >= 0 && bracket >= 0, "{what}: unbalanced nesting");
    }
    assert_eq!(brace, 0, "{what}: unbalanced braces");
    assert_eq!(bracket, 0, "{what}: unbalanced brackets");
    assert!(!in_str, "{what}: unterminated string");
    assert!(!s.contains("NaN") && !s.contains("Infinity"), "{what}: non-finite number");
}

/// One-worker config so trace publishing is contention-free and the
/// sampled-trace counts below are exact (publish drops on `try_lock`
/// contention by design).
fn one_worker_config() -> AppConfig {
    let mut cfg = AppConfig::default();
    cfg.coordinator.workers = 1;
    cfg
}

/// Every sampled decision produces a trace whose stage durations sum
/// *exactly* to its end-to-end latency, and the stage histograms see
/// one sample per completed decision.
#[test]
fn traces_decompose_exactly_and_fill_stage_histograms() {
    let coord = Coordinator::start(&one_worker_config()).unwrap();
    let handle = coord.handle();
    handle.trace_recorder().set_enabled(true);
    let plan = handle.prepare(PlanSpec::Inference).unwrap();
    let n = 16usize;
    let pending: Vec<_> = (0..n)
        .map(|_| {
            plan.submit(DecisionParams::Inference {
                prior: 0.57,
                likelihood: 0.77,
                likelihood_not: 0.655,
            })
            .unwrap()
        })
        .collect();
    for p in pending {
        p.wait_timeout(Duration::from_secs(30)).unwrap();
    }
    let traces = handle.trace_recorder().drain();
    assert_eq!(traces.len(), n, "every decision is sampled at sample_every = 1");
    for t in &traces {
        let mut prev = 0u64;
        for &s in t.stamps() {
            assert!(s >= prev, "stamps must be monotone: {:?}", t.stamps());
            prev = s;
        }
        let sum: u64 = Stage::ALL.iter().map(|&s| t.stage_ns(s)).sum();
        assert_eq!(sum, t.end_to_end_ns(), "stage durations must telescope exactly");
        assert!(t.end_to_end_ns() > 0);
    }
    let swept: u64 = traces.iter().map(|t| t.stage_ns(Stage::Sweep)).sum();
    assert!(swept > 0, "native backend reports real sweep spans");
    let snap = handle.metrics().snapshot();
    assert_eq!(snap.completed, n as u64);
    for stage in Stage::ALL {
        assert_eq!(
            snap.stage_hist(stage).count(),
            n as u64,
            "stage {} histogram sees every sampled decision",
            stage.name()
        );
    }
    assert!(snap.latency_quantile_ns(0.99) >= snap.latency_quantile_ns(0.5));
    coord.shutdown();
}

/// The exposition carries p50/p99/p999 for end-to-end latency and for
/// every stage, plus the hardware and plan-cache counter families; the
/// JSON twin and the Chrome trace dump are structurally well-formed.
#[test]
fn exposition_covers_every_stage_and_dumps_valid_chrome_trace() {
    let coord = Coordinator::start(&AppConfig::default()).unwrap();
    let handle = coord.handle();
    handle.trace_recorder().set_enabled(true);
    let inference = handle.prepare(PlanSpec::Inference).unwrap();
    let fusion = handle.prepare(PlanSpec::Fusion { modalities: 2 }).unwrap();
    let pending: Vec<_> = (0..12)
        .map(|i| {
            if i % 2 == 0 {
                inference
                    .submit(DecisionParams::Inference {
                        prior: 0.57,
                        likelihood: 0.77,
                        likelihood_not: 0.655,
                    })
                    .unwrap()
            } else {
                fusion
                    .submit(DecisionParams::Fusion { posteriors: vec![0.8, 0.7] })
                    .unwrap()
            }
        })
        .collect();
    for p in pending {
        p.wait_timeout(Duration::from_secs(30)).unwrap();
    }
    let text = handle.exposition();
    for q in ["0.5", "0.99", "0.999"] {
        assert!(
            text.contains(&format!("decision_latency_ns{{quantile=\"{q}\"}}")),
            "missing latency quantile {q}:\n{text}"
        );
    }
    for stage in Stage::ALL {
        for q in ["0.5", "0.99", "0.999"] {
            let line = format!("decision_stage_ns{{stage=\"{}\",quantile=\"{q}\"}}", stage.name());
            assert!(text.contains(&line), "missing {line}");
        }
    }
    for family in [
        "decisions_submitted_total",
        "decisions_completed_total",
        "plan_cache_hits_total",
        "hardware_bits_pulsed_total",
        "hardware_energy_nj_total",
    ] {
        assert!(text.contains(family), "missing family {family}:\n{text}");
    }
    let json = handle.exposition_json();
    assert_jsonish(&json, "exposition json");
    assert!(json.contains("\"stages\""));

    let traces = handle.trace_recorder().drain();
    assert!(!traces.is_empty());
    let chrome = chrome_trace_json(&traces);
    assert_jsonish(&chrome, "chrome trace");
    assert_eq!(
        chrome.matches("\"ph\":\"X\"").count(),
        traces.len() * (1 + Stage::COUNT),
        "one decision event plus one per stage"
    );
    // Two plans -> two tracks in the trace viewer.
    assert!(chrome.contains(&format!("\"tid\":{}", inference.plan().id())));
    assert!(chrome.contains(&format!("\"tid\":{}", fusion.plan().id())));
    coord.shutdown();
}

/// Tracing is sampled and droppable, never load-bearing: with the
/// recorder disabled nothing is recorded, and a 1-in-4 sampling rate
/// traces only its share while *metrics* still see every decision.
#[test]
fn sampling_and_disable_gate_recording_without_losing_metrics() {
    let coord = Coordinator::start(&one_worker_config()).unwrap();
    let handle = coord.handle();
    let plan = handle.prepare(PlanSpec::Inference).unwrap();
    let decide = |k: usize| {
        let pending: Vec<_> = (0..k)
            .map(|_| {
                plan.submit(DecisionParams::Inference {
                    prior: 0.57,
                    likelihood: 0.77,
                    likelihood_not: 0.655,
                })
                .unwrap()
            })
            .collect();
        for p in pending {
            p.wait_timeout(Duration::from_secs(30)).unwrap();
        }
    };
    // Disabled (the default): no traces, full serving metrics.
    decide(8);
    assert_eq!(handle.trace_recorder().len(), 0);
    assert_eq!(handle.metrics().snapshot().completed, 8);
    // 1-in-4 sampling: a quarter of the load is traced.
    handle.trace_recorder().set_enabled(true);
    handle.trace_recorder().set_sample_every(4);
    decide(16);
    let traces = handle.trace_recorder().drain();
    assert_eq!(traces.len(), 4, "1-in-4 sampling over 16 decisions");
    let snap = handle.metrics().snapshot();
    assert_eq!(snap.completed, 24, "metrics count every decision regardless of sampling");
    assert_eq!(snap.stage_hist(Stage::Sweep).count(), 4, "stage quantiles are trace-fed");
    coord.shutdown();
}

/// End-to-end through the video pipeline: `parse-video --trace-out`
/// semantics — the report carries decomposing traces that export to a
/// well-formed Chrome trace.
#[test]
fn video_pipeline_traces_export_to_chrome_format() {
    let cfg = PipelineConfig {
        trace: true,
        ..PipelineConfig::deterministic(ScenarioSpec::mixed_traffic(), 12, 5, 1024)
    };
    let report = pipeline::run(&cfg).unwrap();
    assert!(!report.traces.is_empty(), "traced run must collect traces");
    for t in &report.traces {
        let sum: u64 = Stage::ALL.iter().map(|&s| t.stage_ns(s)).sum();
        assert_eq!(sum, t.end_to_end_ns());
    }
    let chrome = chrome_trace_json(&report.traces);
    assert_jsonish(&chrome, "pipeline chrome trace");
    assert!(chrome.contains("\"name\":\"sweep\""));
}
