//! Acceptance suite for the plan-centric serving API v2
//! (prepare-once / decide-many):
//!
//! * decisions served through prepared plans are **bit-identical** to
//!   the pre-redesign `bayes::batch` engines on shared seeds, for every
//!   decision kind (the unified-netlist regression pin);
//! * the legacy `DecisionKind` shim and the plan path agree decision for
//!   decision;
//! * the shared `PlanCache` behaves: concurrent `prepare` of one spec
//!   yields one entry (hit/miss metrics asserted), eviction is LRU;
//! * per-plan latency counters and the `Policy` knobs (deadline, bits)
//!   are observable end to end.

use std::sync::Arc;
use std::time::Duration;

use bayes_mem::bayes::{BatchedFusion, BatchedInference, InferenceQuery};
use bayes_mem::config::AppConfig;
use bayes_mem::coordinator::{
    Coordinator, Decision, DecisionKind, DecisionParams, NetworkOverride, PlanSpec, Policy,
    PreparedPlan,
};
use bayes_mem::network::BayesNet;
use bayes_mem::stochastic::SneBank;
use bayes_mem::util::Rng;

/// One worker so the worker-bank decision order equals submission order.
fn single_worker_config(seed: u64) -> AppConfig {
    let mut cfg = AppConfig::default();
    cfg.seed = seed;
    cfg.coordinator.workers = 1;
    cfg
}

fn inference_params(n: usize, seed: u64) -> Vec<DecisionParams> {
    let mut rng = Rng::seeded(seed);
    (0..n)
        .map(|_| DecisionParams::Inference {
            prior: rng.range_f64(0.1, 0.9),
            likelihood: rng.range_f64(0.5, 0.95),
            likelihood_not: rng.range_f64(0.05, 0.5),
        })
        .collect()
}

fn fusion_params(n: usize, seed: u64) -> Vec<DecisionParams> {
    let mut rng = Rng::seeded(seed);
    (0..n)
        .map(|_| DecisionParams::Fusion {
            posteriors: vec![rng.range_f64(0.2, 0.95), rng.range_f64(0.2, 0.95)],
        })
        .collect()
}

fn serve_plan(cfg: &AppConfig, spec: PlanSpec, params: &[DecisionParams]) -> Vec<Decision> {
    let coord = Coordinator::start(cfg).unwrap();
    let plan = coord.handle().prepare(spec).unwrap();
    let decisions = plan
        .decide_batch(params)
        .into_iter()
        .map(|d| d.unwrap())
        .collect();
    coord.shutdown();
    decisions
}

#[test]
fn plan_served_inference_is_bit_identical_to_batched_engine() {
    let params = inference_params(64, 21);
    let cfg = single_worker_config(4242);
    let served = serve_plan(&cfg, PlanSpec::Inference, &params);

    // The lone worker's bank is seeded `config.seed ^ (0 << 32)`; replay
    // the exact stream through the pre-redesign batched engine on an
    // identically-seeded bank. Per-decision encode/finish order is
    // independent of how the dynamic batcher sliced the stream.
    let queries: Vec<InferenceQuery> = params
        .iter()
        .map(|p| match p {
            DecisionParams::Inference { prior, likelihood, likelihood_not } => InferenceQuery {
                prior: *prior,
                likelihood: *likelihood,
                likelihood_not: *likelihood_not,
            },
            _ => unreachable!(),
        })
        .collect();
    let mut bank = SneBank::new(cfg.sne.clone(), cfg.seed).unwrap();
    let batched = BatchedInference::new().infer_batch(&mut bank, &queries);
    for (i, (d, r)) in served.iter().zip(&batched).enumerate() {
        let r = r.as_ref().unwrap();
        assert_eq!(
            d.posterior, r.posterior,
            "decision {i}: plan path diverged from BatchedInference"
        );
    }
}

#[test]
fn plan_served_fusion_is_bit_identical_to_batched_engine() {
    let params = fusion_params(48, 22);
    let cfg = single_worker_config(31337);
    let served = serve_plan(&cfg, PlanSpec::Fusion { modalities: 2 }, &params);

    let rows: Vec<Vec<f64>> = params
        .iter()
        .map(|p| match p {
            DecisionParams::Fusion { posteriors } => posteriors.clone(),
            _ => unreachable!(),
        })
        .collect();
    let row_refs: Vec<&[f64]> = rows.iter().map(Vec::as_slice).collect();
    let mut bank = SneBank::new(cfg.sne.clone(), cfg.seed).unwrap();
    let batched = BatchedFusion::new().fuse_batch(&mut bank, &row_refs);
    for (i, (d, r)) in served.iter().zip(&batched).enumerate() {
        assert_eq!(
            d.posterior,
            *r.as_ref().unwrap(),
            "decision {i}: plan path diverged from BatchedFusion"
        );
    }
}

#[test]
fn legacy_shim_and_plan_path_serve_identical_streams() {
    // The same decision stream through (a) DecisionKind submission and
    // (b) prepared-plan submission on identically-configured
    // coordinators must be bit-identical.
    let params = inference_params(32, 23);
    let cfg = single_worker_config(777);
    let via_plan = serve_plan(&cfg, PlanSpec::Inference, &params);

    let coord = Coordinator::start(&cfg).unwrap();
    let h = coord.handle();
    let pending: Vec<_> = params
        .iter()
        .map(|p| {
            let DecisionParams::Inference { prior, likelihood, likelihood_not } = *p else {
                unreachable!()
            };
            h.submit(DecisionKind::Inference { prior, likelihood, likelihood_not }).unwrap()
        })
        .collect();
    let via_shim: Vec<Decision> = pending
        .into_iter()
        .map(|p| p.wait_timeout(Duration::from_secs(30)).unwrap())
        .collect();
    coord.shutdown();

    for (i, (a, b)) in via_plan.iter().zip(&via_shim).enumerate() {
        assert_eq!(a.posterior, b.posterior, "decision {i} diverged across APIs");
        assert_eq!(a.exact, b.exact);
    }
}

fn diamond() -> Arc<BayesNet> {
    let mut net = BayesNet::named("diamond");
    net.add_root("a", 0.4).unwrap();
    net.add_node("b", &["a"], &[0.2, 0.9]).unwrap();
    net.add_node("c", &["a"], &[0.7, 0.1]).unwrap();
    net.add_node("d", &["b", "c"], &[0.1, 0.5, 0.6, 0.95]).unwrap();
    Arc::new(net)
}

fn diamond_spec() -> PlanSpec {
    PlanSpec::Network {
        net: diamond(),
        query: "a".into(),
        evidence: vec![("d".into(), true)],
    }
}

#[test]
fn prepared_network_plan_matches_direct_evaluation_stream() {
    let cfg = single_worker_config(99);
    let params = vec![DecisionParams::Network { overrides: vec![] }; 8];
    let served = serve_plan(&cfg, diamond_spec(), &params);

    // Direct netlist evaluation on an identically-seeded bank, decision
    // after decision — the worker must behave exactly like this loop.
    let net = diamond();
    let nl = bayes_mem::network::compile_query(&net, "a", &[("d", true)]).unwrap();
    let mut bank = SneBank::new(cfg.sne.clone(), cfg.seed).unwrap();
    let mut eval = bayes_mem::network::NetlistEvaluator::new();
    for (i, d) in served.iter().enumerate() {
        let direct = eval.evaluate(&mut bank, &nl).unwrap();
        assert_eq!(d.posterior, direct.posterior, "decision {i} diverged");
    }
    // The exact annotation is the prepare-time enumeration.
    let (exact, _) =
        bayes_mem::network::exact_posterior_by_name(&net, "a", &[("d", true)]).unwrap();
    for d in &served {
        assert_eq!(d.exact, exact);
    }
}

#[test]
fn concurrent_prepare_of_one_spec_yields_one_cache_entry() {
    let coord = Coordinator::start(&single_worker_config(1)).unwrap();
    let h = coord.handle();
    const THREADS: usize = 8;
    let plans: Vec<_> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..THREADS)
            .map(|_| {
                let h = h.clone();
                // Each thread builds its own Arc<BayesNet>: cache
                // identity must be structural, not pointer-based.
                s.spawn(move || h.prepare(diamond_spec()).unwrap())
            })
            .collect();
        handles.into_iter().map(|t| t.join().unwrap()).collect()
    });
    // One entry, one compile; everyone shares the same Arc.
    assert_eq!(h.plan_cache().len(), 1);
    let first = plans[0].plan();
    assert!(plans.iter().all(|p| Arc::ptr_eq(p.plan(), first)));
    let snap = h.metrics().snapshot();
    assert_eq!(snap.plan_misses, 1, "exactly one compile");
    assert_eq!(snap.plan_hits, (THREADS - 1) as u64);
    coord.shutdown();
}

#[test]
fn plan_cache_eviction_is_lru_under_concurrency() {
    let mut cfg = single_worker_config(2);
    cfg.coordinator.plan_cache_capacity = 2;
    let coord = Coordinator::start(&cfg).unwrap();
    let h = coord.handle();
    // Concurrent prepares of distinct specs never exceed capacity and
    // account every call as a hit or a miss.
    std::thread::scope(|s| {
        for m in 2..6usize {
            let h = h.clone();
            s.spawn(move || {
                for _ in 0..8 {
                    h.prepare(PlanSpec::Fusion { modalities: m }).unwrap();
                }
            });
        }
    });
    assert!(h.plan_cache().len() <= 2);
    let snap = h.metrics().snapshot();
    assert_eq!(snap.plan_hits + snap.plan_misses, 32);
    assert!(snap.plan_misses >= 4, "four distinct specs must each compile at least once");

    // Deterministic LRU order: touch A, then C evicts B.
    let a = PlanSpec::Fusion { modalities: 12 };
    let b = PlanSpec::Fusion { modalities: 13 };
    let c = PlanSpec::Fusion { modalities: 14 };
    h.prepare(a.clone()).unwrap();
    h.prepare(b.clone()).unwrap();
    h.prepare(a.clone()).unwrap();
    h.prepare(c.clone()).unwrap();
    assert!(h.plan_cache().contains(&a));
    assert!(!h.plan_cache().contains(&b));
    assert!(h.plan_cache().contains(&c));
    coord.shutdown();
}

#[test]
fn per_plan_latency_counters_partition_completions() {
    let coord = Coordinator::start(&single_worker_config(3)).unwrap();
    let h = coord.handle();
    let inf = h.prepare(PlanSpec::Inference).unwrap();
    let fus = h.prepare(PlanSpec::Fusion { modalities: 2 }).unwrap();
    for d in inf.decide_batch(&inference_params(6, 5)) {
        d.unwrap();
    }
    for d in fus.decide_batch(&fusion_params(4, 6)) {
        d.unwrap();
    }
    let snap = h.metrics().snapshot();
    assert_eq!(snap.plan_latency(inf.plan().id()).unwrap().completed, 6);
    assert_eq!(snap.plan_latency(fus.plan().id()).unwrap().completed, 4);
    let total: u64 = snap.per_plan.iter().map(|p| p.completed).sum();
    assert_eq!(total, snap.completed, "per-plan counters must partition completions");
    assert!(snap.plan_latency(inf.plan().id()).unwrap().mean_latency_us() >= 0.0);
    coord.shutdown();
}

#[test]
fn policy_bits_and_deadline_apply_per_plan_handle() {
    let coord = Coordinator::start(&single_worker_config(4)).unwrap();
    let h = coord.handle();
    let base = h.prepare(PlanSpec::Inference).unwrap();
    let long = base
        .clone()
        .with_policy(Policy { bits: Some(2000), ..Policy::default() });
    let d = long
        .decide(DecisionParams::Inference { prior: 0.57, likelihood: 0.77, likelihood_not: 0.655 })
        .unwrap();
    // 2000 bits × 4 µs/bit = 8 ms of virtual hardware time.
    assert!((d.hardware_ns - 8_000_000.0).abs() < 1e-6);
    // The default-policy handle still runs at the configured 100 bits.
    let d = base
        .decide(DecisionParams::Inference { prior: 0.57, likelihood: 0.77, likelihood_not: 0.655 })
        .unwrap();
    assert!((d.hardware_ns - 400_000.0).abs() < 1e-6);
    // Impossible deadline through the policy.
    let strict = base
        .clone()
        .with_policy(Policy { deadline: Some(Duration::from_nanos(1)), ..Policy::default() });
    let err = strict
        .decide(DecisionParams::Inference { prior: 0.5, likelihood: 0.7, likelihood_not: 0.2 })
        .unwrap_err();
    assert!(matches!(err, bayes_mem::Error::Deadline(_)));
    coord.shutdown();
}

#[test]
fn policy_bits_is_rejected_on_the_pjrt_backend() {
    // PJRT artifact shapes are baked at compile time: a stream-length
    // override must be a typed rejection, not silently ignored. (The
    // handle rejects before any worker runs, so no artifacts are needed.)
    let mut cfg = single_worker_config(7);
    cfg.coordinator.backend = bayes_mem::config::Backend::Pjrt;
    let coord = Coordinator::start(&cfg).unwrap();
    let plan = coord
        .handle()
        .prepare(PlanSpec::Inference)
        .unwrap()
        .with_policy(Policy { bits: Some(512), ..Policy::default() });
    let err = plan
        .submit(DecisionParams::Inference { prior: 0.5, likelihood: 0.7, likelihood_not: 0.2 })
        .unwrap_err();
    assert!(matches!(err, bayes_mem::Error::Config(_)), "got {err}");
    assert!(err.to_string().contains("native backend"), "{err}");
    // The anytime knobs need the native backend for the same reason.
    for policy in [
        Policy { threshold: Some(0.5), ..Policy::default() },
        Policy { max_half_width: Some(0.05), ..Policy::default() },
        Policy {
            allow_partial: true,
            deadline: Some(Duration::from_micros(400)),
            ..Policy::default()
        },
    ] {
        let plan = coord.handle().prepare(PlanSpec::Inference).unwrap().with_policy(policy);
        let err = plan
            .submit(DecisionParams::Inference { prior: 0.5, likelihood: 0.7, likelihood_not: 0.2 })
            .unwrap_err();
        assert!(err.to_string().contains("native backend"), "{policy:?}: {err}");
    }
    coord.shutdown();
}

#[test]
fn anytime_policy_applies_through_plan_handles() {
    // A network plan served under an accuracy-targeted policy: decisions
    // stop early, stamped with bits_used/confidence, and the non-anytime
    // handle on the same plan still runs the full sweep.
    let mut cfg = single_worker_config(8);
    cfg.sne.n_bits = 16_384;
    let coord = Coordinator::start(&cfg).unwrap();
    let h = coord.handle();
    let base = h.prepare(diamond_spec()).unwrap();
    let anytime = base
        .clone()
        .with_policy(Policy { max_half_width: Some(0.05), ..Policy::default() });
    let d = anytime.decide(DecisionParams::Network { overrides: vec![] }).unwrap();
    assert!(d.stopped_early(), "stop {:?}", d.stop);
    assert!(d.bits_used < 16_384);
    assert!(d.confidence <= 0.05);
    assert!((d.posterior - d.exact).abs() < 0.25, "{} vs {}", d.posterior, d.exact);
    let full = base.decide(DecisionParams::Network { overrides: vec![] }).unwrap();
    assert_eq!(full.bits_used, 16_384);
    assert!(!full.stopped_early());
    let snap = h.metrics().snapshot();
    assert_eq!(snap.early_exit_total(), 1);
    assert!(snap.bits_saved() > 0);
    coord.shutdown();
}

#[test]
fn oversized_fusion_is_rejected_by_both_apis() {
    let coord = Coordinator::start(&single_worker_config(5)).unwrap();
    let h = coord.handle();
    let err = h.prepare(PlanSpec::Fusion { modalities: 200 }).unwrap_err();
    assert!(err.to_string().contains("modality cap"), "{err}");
    let err = h.submit(DecisionKind::Fusion { posteriors: vec![0.5; 200] }).unwrap_err();
    assert!(err.to_string().contains("modality cap"), "{err}");
    assert!(h.metrics().snapshot().rejected >= 2);
    coord.shutdown();
}

#[test]
fn network_prepare_propagates_typed_errors() {
    let coord = Coordinator::start(&single_worker_config(6)).unwrap();
    let h = coord.handle();
    let bad = PlanSpec::Network { net: diamond(), query: "zz".into(), evidence: vec![] };
    assert!(matches!(h.prepare(bad).unwrap_err(), bayes_mem::Error::Network(_)));
    // Served network decisions always carry a finite exact reference.
    let plan = h.prepare(diamond_spec()).unwrap();
    let d = plan.decide(DecisionParams::Network { overrides: vec![] }).unwrap();
    assert!(d.exact.is_finite());
    coord.shutdown();
}

/// The diamond with a different root prior — structurally identical to
/// [`diamond_spec`], so preparing it must **rebind** the cached plan.
fn diamond_spec_with_prior(prior: f64) -> PlanSpec {
    let mut net = BayesNet::named("diamond");
    net.add_root("a", prior).unwrap();
    net.add_node("b", &["a"], &[0.2, 0.9]).unwrap();
    net.add_node("c", &["a"], &[0.7, 0.1]).unwrap();
    net.add_node("d", &["b", "c"], &[0.1, 0.5, 0.6, 0.95]).unwrap();
    PlanSpec::Network { net: Arc::new(net), query: "a".into(), evidence: vec![("d".into(), true)] }
}

#[test]
fn overridden_decisions_are_served_and_baked_bits_stay_identical() {
    // A stream mixing baked decisions (empty overrides — the
    // pre-parameterization path, bit-for-bit) with per-decision prior
    // overrides on the same prepared plan.
    let cfg = single_worker_config(91);
    let baked = DecisionParams::Network { overrides: vec![] };
    let hot = DecisionParams::Network { overrides: vec![NetworkOverride::new("a", 0, 0.75)] };
    let params =
        vec![baked.clone(), hot.clone(), baked.clone(), hot.clone(), baked, hot.clone()];
    let served = serve_plan(&cfg, diamond_spec(), &params);

    // Mirror the exact worker-bank stream through the plan's own
    // decide_on path on an identically-seeded bank: baked decisions run
    // the value-optimized netlist (bit-identical to pre-refactor),
    // overridden ones run the structural twin with rewritten inputs.
    let plan = PreparedPlan::compile(diamond_spec()).unwrap();
    let mut bank = SneBank::new(cfg.sne.clone(), cfg.seed).unwrap();
    let mut eval = bayes_mem::network::NetlistEvaluator::new();
    for (i, (p, d)) in params.iter().zip(&served).enumerate() {
        let direct = plan.decide_on(&mut bank, &mut eval, p).unwrap();
        assert_eq!(d.posterior, direct, "decision {i} diverged from the direct plan path");
    }

    // The exact annotation moves with the binding: overridden decisions
    // carry VE on the overridden network, baked ones the prepare-time
    // reference.
    let PlanSpec::Network { net: hot_net, .. } = diamond_spec_with_prior(0.75) else {
        unreachable!()
    };
    let (exact_hot, _) =
        bayes_mem::network::exact_posterior_by_name(&hot_net, "a", &[("d", true)]).unwrap();
    let net = diamond();
    let (exact_baked, _) =
        bayes_mem::network::exact_posterior_by_name(&net, "a", &[("d", true)]).unwrap();
    for (p, d) in params.iter().zip(&served) {
        let expect = match p {
            DecisionParams::Network { overrides } if overrides.is_empty() => exact_baked,
            _ => exact_hot,
        };
        assert_eq!(d.exact, expect);
    }
    assert!((exact_hot - exact_baked).abs() > 0.05, "override must actually move the posterior");
}

#[test]
fn same_structure_prepares_rebind_with_zero_misses_after_warmup() {
    let coord = Coordinator::start(&single_worker_config(92)).unwrap();
    let h = coord.handle();
    h.prepare(diamond_spec()).unwrap(); // cold: the one compile
    h.prepare(diamond_spec_with_prior(0.55)).unwrap(); // same structure: rebind
    h.prepare(diamond_spec()).unwrap(); // warm: full-spec hit
    h.prepare(diamond_spec_with_prior(0.55)).unwrap(); // warm: rebound entry hit
    let snap = h.metrics().snapshot();
    assert_eq!(snap.plan_misses, 1, "zero plan-cache misses after warmup");
    assert_eq!(snap.plan_rebinds, 1, "one structural rebind, never a recompile");
    assert_eq!(snap.plan_hits, 2, "warm prepares of both bindings are hits");
    assert_eq!(h.plan_cache().len(), 2, "baked and rebound bindings are distinct entries");
    coord.shutdown();
}
