//! Property-based invariant suites (via the in-tree `proptest_lite`
//! driver): stochastic-computing algebra, CORDIV, correlation metrics,
//! batcher/router behaviour, config round-trips, and the
//! Bayesian-network compiler (random-DAG convergence + validator
//! rejection of injected defects).

use std::time::{Duration, Instant};

use bayes_mem::bayes::{exact_fusion_m, exact_posterior, FusionOperator, InferenceOperator};
use bayes_mem::coordinator::{Batcher, DecisionKind, DecisionRequest, PlanCache};
use bayes_mem::logic::cordiv;
use bayes_mem::network::{self, compile_query, BayesNet, NetlistEvaluator, NodeSpec};
use bayes_mem::stochastic::{pair_counts, pearson, scc, Bitstream, SneBank, SneConfig};
use bayes_mem::util::proptest_lite::check;
use bayes_mem::util::Rng;

fn random_stream(rng: &mut Rng, n: usize) -> Bitstream {
    let p = rng.f64();
    let mut s = Bitstream::zeros(n);
    for i in 0..n {
        if rng.bernoulli(p) {
            s.set(i, true);
        }
    }
    s
}

#[test]
fn prop_bitstream_roundtrip_and_complement() {
    check("bitstream pack/unpack + complement", 128, |rng| {
        let n = rng.range_usize(1, 400);
        let s = random_stream(rng, n);
        let bits: Vec<bool> = s.iter().collect();
        assert_eq!(Bitstream::from_bits(&bits), s);
        // Complement density.
        assert_eq!(s.count_ones() + s.not().count_ones(), n);
        // Double complement is identity.
        assert_eq!(s.not().not(), s);
    });
}

#[test]
fn prop_gate_bounds() {
    check("AND ≤ min, OR ≥ max, XOR bounds", 96, |rng| {
        let n = rng.range_usize(64, 512);
        let a = random_stream(rng, n);
        let b = random_stream(rng, n);
        let and = a.and(&b).unwrap();
        let or = a.or(&b).unwrap();
        let xor = a.xor(&b).unwrap();
        assert!(and.value() <= a.value().min(b.value()) + 1e-12);
        assert!(or.value() >= a.value().max(b.value()) - 1e-12);
        // AND + OR = A + B exactly (inclusion-exclusion at bit level).
        assert!((and.value() + or.value() - a.value() - b.value()).abs() < 1e-12);
        // XOR = OR − AND.
        assert!((xor.value() - (or.value() - and.value())).abs() < 1e-12);
    });
}

#[test]
fn prop_mux_bounded_by_and_or() {
    // Bitwise, out_k ∈ {a_k, b_k}: so AND(a,b) ⊆ out ⊆ OR(a,b) exactly
    // (the convex-combination law holds only in expectation).
    check("MUX between AND and OR", 96, |rng| {
        let n = rng.range_usize(64, 512);
        let a = random_stream(rng, n);
        let b = random_stream(rng, n);
        let sel = random_stream(rng, n);
        let out = a.mux(&b, &sel).unwrap();
        let and = a.and(&b).unwrap();
        let or = a.or(&b).unwrap();
        // Subset checks are exact bit algebra.
        assert_eq!(and.and(&out).unwrap(), and, "AND ⊄ out");
        assert_eq!(or.or(&out).unwrap(), or, "out ⊄ OR");
        assert!(out.value() >= and.value() && out.value() <= or.value());
    });
}

#[test]
fn prop_cordiv_output_is_probability() {
    check("CORDIV stays in [0,1] and respects subsets", 96, |rng| {
        let n = rng.range_usize(64, 1024);
        let b = random_stream(rng, n);
        let mask = random_stream(rng, n);
        let a = b.and(&mask).unwrap(); // a ⊆ b by construction
        let q = cordiv(&a, &b).unwrap();
        let v = q.value();
        assert!((0.0..=1.0).contains(&v));
        // With a ⊆ b and enough divisor mass, q approximates a/b.
        if b.count_ones() > 32 {
            let want = a.value() / b.value();
            assert!((v - want).abs() < 0.35, "q {v} vs {want}");
        }
    });
}

#[test]
fn prop_correlation_metrics_bounded_and_consistent() {
    check("ρ, SCC ∈ [−1,1]; counts sum to n", 128, |rng| {
        let n = rng.range_usize(8, 600);
        let x = random_stream(rng, n);
        let y = random_stream(rng, n);
        let pc = pair_counts(&x, &y).unwrap();
        assert_eq!(pc.n() as usize, n);
        assert_eq!((pc.a + pc.b) as usize, x.count_ones());
        assert_eq!((pc.a + pc.c) as usize, y.count_ones());
        let r = pearson(&x, &y).unwrap();
        let s = scc(&x, &y).unwrap();
        assert!((-1.0 - 1e-9..=1.0 + 1e-9).contains(&r));
        assert!((-1.0 - 1e-9..=1.0 + 1e-9).contains(&s));
        // Symmetry of both metrics.
        assert!((pearson(&y, &x).unwrap() - r).abs() < 1e-12);
        assert!((scc(&y, &x).unwrap() - s).abs() < 1e-12);
    });
}

#[test]
fn prop_operators_track_exact_bayes() {
    check("operators within MC error of exact Bayes", 24, |rng| {
        let n_bits = 20_000;
        let mut bank =
            SneBank::new(SneConfig { n_bits, ..Default::default() }, rng.next_u64()).unwrap();
        let pa = rng.range_f64(0.05, 0.95);
        let pba = rng.range_f64(0.05, 0.95);
        let pbna = rng.range_f64(0.05, 0.95);
        let r = InferenceOperator::default().try_infer(&mut bank, pa, pba, pbna).unwrap();
        let tol = 0.08; // CORDIV variance blows up for tiny denominators
        assert!(
            (r.posterior - exact_posterior(pa, pba, pbna)).abs() < tol,
            "inference ({pa:.2},{pba:.2},{pbna:.2}): {} vs {}",
            r.posterior,
            exact_posterior(pa, pba, pbna)
        );
        let p1 = rng.range_f64(0.1, 0.9);
        let p2 = rng.range_f64(0.1, 0.9);
        let f = FusionOperator::default().fuse2(&mut bank, p1, p2).unwrap();
        assert!(
            (f.fused - exact_fusion_m(&[p1, p2])).abs() < tol,
            "fusion ({p1:.2},{p2:.2}): {} vs {}",
            f.fused,
            exact_fusion_m(&[p1, p2])
        );
    });
}

#[test]
fn prop_posterior_monotone_in_prior() {
    check("posterior increases with prior (exact)", 64, |rng| {
        let pba = rng.range_f64(0.1, 0.9);
        let pbna = rng.range_f64(0.1, 0.9);
        let p1 = rng.range_f64(0.0, 0.5);
        let p2 = p1 + rng.range_f64(0.0, 0.5);
        assert!(exact_posterior(p2, pba, pbna) >= exact_posterior(p1, pba, pbna) - 1e-12);
    });
}

fn req(cache: &PlanCache, rng: &mut Rng, id: u64) -> DecisionRequest {
    let (tx, rx) = std::sync::mpsc::channel();
    std::mem::forget(rx);
    let kind = if rng.bernoulli(0.5) {
        DecisionKind::Inference {
            prior: rng.f64(),
            likelihood: rng.f64(),
            likelihood_not: rng.f64(),
        }
    } else {
        DecisionKind::Fusion { posteriors: vec![rng.f64(), rng.f64()] }
    };
    let (spec, params) = kind.into_plan_parts();
    DecisionRequest {
        id,
        plan: cache.prepare(spec).unwrap(),
        params,
        enqueued: Instant::now(),
        deadline: None,
        bits: None,
        threshold: None,
        max_half_width: None,
        allow_partial: false,
        trace: None,
        reply: tx,
    }
}

#[test]
fn prop_batcher_conserves_requests() {
    check("batcher: no request lost or duplicated, caps respected", 64, |rng| {
        let cache = PlanCache::new(8);
        let max_batch = rng.range_usize(1, 9);
        let mut batcher = Batcher::new(max_batch, Duration::from_millis(1));
        let n = rng.range_usize(1, 120);
        let mut out_ids = Vec::new();
        for id in 0..n as u64 {
            if let Some(batch) = batcher.push(req(&cache, rng, id)) {
                assert!(batch.len() <= max_batch);
                assert!(batch.requests.iter().all(|r| r.plan.id() == batch.plan.id()));
                out_ids.extend(batch.requests.iter().map(|r| r.id));
            }
        }
        for batch in batcher.flush_all() {
            out_ids.extend(batch.requests.iter().map(|r| r.id));
        }
        out_ids.sort_unstable();
        let expect: Vec<u64> = (0..n as u64).collect();
        assert_eq!(out_ids, expect);
    });
}

#[test]
fn prop_config_document_roundtrip() {
    use bayes_mem::util::tomlmini::Document;
    check("tomlmini parses what it prints", 64, |rng| {
        let n_bits = rng.range_usize(1, 100_000);
        let workers = rng.range_usize(1, 64);
        let vth = rng.range_f64(1.5, 3.0);
        let text = format!(
            "[sne]\nn_bits = {n_bits}\n[coordinator]\nworkers = {workers}\n[device]\nvth_mean = {vth}\n"
        );
        let doc = Document::parse(&text).unwrap();
        assert_eq!(doc.usize_or("sne.n_bits", 0), n_bits);
        assert_eq!(doc.usize_or("coordinator.workers", 0), workers);
        assert!((doc.f64_or("device.vth_mean", 0.0) - vth).abs() < 1e-9);
    });
}

/// Random valid DAG over `n` binary nodes: each node takes up to 3 of
/// the earlier nodes as parents, CPT probabilities in `[0.15, 0.85]` so
/// no evidence configuration becomes vanishingly rare.
fn random_net_parts(rng: &mut Rng, n: usize) -> Vec<NodeSpec> {
    let mut nodes = Vec::with_capacity(n);
    for i in 0..n {
        let mut parents = Vec::new();
        for j in 0..i {
            if rng.bernoulli(0.4) {
                parents.push(j);
            }
        }
        parents.truncate(3);
        let k = parents.len();
        let cpt: Vec<(u32, f64)> =
            (0..(1u32 << k)).map(|a| (a, 0.15 + 0.7 * rng.f64())).collect();
        nodes.push(NodeSpec { name: format!("n{i}"), parents, cpt });
    }
    nodes
}

#[test]
fn prop_compiled_network_converges_to_exact_enumeration() {
    // Random 3-7-node DAGs: the compiled-netlist posterior approaches
    // the full-joint exact posterior as the stream length grows. Judged
    // on mean error across cases (any single stochastic readout has
    // irreducible sampling noise).
    let mut err_short = Vec::new();
    let mut err_long = Vec::new();
    check("compiled netlist converges to exact posterior", 16, |rng| {
        let n = rng.range_usize(3, 8);
        let net = BayesNet::from_parts("rand", random_net_parts(rng, n));
        net.validate().unwrap();
        let query = "n0";
        let last = format!("n{}", n - 1);
        let evidence = [(last.as_str(), true)];
        let netlist = compile_query(&net, query, &evidence).unwrap();
        let (exact, p_ev) =
            network::exact_posterior_by_name(&net, query, &evidence).unwrap();
        assert!(p_ev > 0.1, "CPT range keeps evidence probable, got {p_ev}");
        let seed = rng.next_u64();
        for (n_bits, errs) in
            [(512usize, &mut err_short), (16_384, &mut err_long)]
        {
            let cfg = SneConfig { n_bits, ..Default::default() };
            let mut bank = SneBank::new(cfg, seed).unwrap();
            let r = NetlistEvaluator::new().evaluate(&mut bank, &netlist).unwrap();
            assert!((0.0..=1.0).contains(&r.posterior));
            errs.push((r.posterior - exact).abs());
        }
    });
    let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
    let (short, long) = (mean(&err_short), mean(&err_long));
    assert!(long < short, "no convergence: 512-bit {short:.4} vs 16384-bit {long:.4}");
    assert!(long < 0.02, "16384-bit mean abs error {long:.4} >= 0.02");
}

#[test]
fn prop_anytime_early_exit_stays_within_reported_half_width() {
    // Random 3-7-node DAGs: an accuracy-targeted anytime stop must (a)
    // reproduce the full sweep exactly when its criteria never fire, and
    // (b) when it exits early, land within the combined confidence
    // bounds of the truncated and full-length posteriors. Marginal
    // queries keep the CORDIV quotient i.i.d. (all-ones denominator), so
    // the Wilson interval is the right yardstick.
    use bayes_mem::network::StopPolicy;
    check("anytime early exit within reported half-width", 16, |rng| {
        let n = rng.range_usize(3, 8);
        let net = BayesNet::from_parts("rand", random_net_parts(rng, n));
        let query = format!("n{}", n - 1); // deepest node: real MUX trees
        let netlist = compile_query(&net, &query, &[]).unwrap();
        let n_bits = 16_384usize;
        let seed = rng.next_u64();
        let cfg = SneConfig { n_bits, ..Default::default() };

        let mut bank_full = SneBank::new(cfg.clone(), seed).unwrap();
        let full =
            NetlistEvaluator::new().evaluate(&mut bank_full, &netlist).unwrap();

        let mut bank_any = SneBank::new(cfg, seed).unwrap();
        let any = NetlistEvaluator::new()
            .evaluate_anytime(
                &mut bank_any,
                &netlist,
                netlist.inputs(),
                &StopPolicy::converged(0.03),
            )
            .unwrap();
        assert!(any.bits_used <= n_bits);
        assert!((0.0..=1.0).contains(&any.posterior));
        if any.bits_used == n_bits {
            // Criteria never fired: must equal the full sweep bitwise.
            assert_eq!(any.posterior, full.posterior);
        } else {
            assert!(any.half_width <= 0.03, "half width {}", any.half_width);
            let full_hw = bayes_mem::util::stats::wilson_half_width(
                (full.posterior * n_bits as f64).round() as u64,
                n_bits as u64,
                bayes_mem::network::ANYTIME_Z,
            );
            assert!(
                (any.posterior - full.posterior).abs()
                    <= any.half_width + full_hw + 0.01,
                "early {} (hw {}) vs full {} (hw {full_hw})",
                any.posterior,
                any.half_width,
                full.posterior
            );
            // Early exit spends fewer pulses.
            assert!(bank_any.ledger().pulses < bank_full.ledger().pulses);
        }
    });
}

#[test]
fn prop_validator_rejects_injected_cycles() {
    check("validator rejects randomly injected cycles", 48, |rng| {
        let n = rng.range_usize(3, 8);
        let mut nodes = random_net_parts(rng, n);
        // Find a (parent -> child) edge and add the reverse edge,
        // expanding the parent's CPT so only the cycle is defective.
        let Some(child) = (0..n).filter(|&i| !nodes[i].parents.is_empty()).last() else {
            return; // all-roots draw: nothing to cycle
        };
        let parent = nodes[child].parents[0];
        let old_cpt: Vec<f64> =
            nodes[parent].cpt.iter().map(|&(_, p)| p).collect();
        nodes[parent].parents.push(child);
        nodes[parent].cpt = (0..old_cpt.len() as u32 * 2)
            .map(|a| (a, old_cpt[(a >> 1) as usize]))
            .collect();
        let net = BayesNet::from_parts("cyclic", nodes);
        let err = net.validate().unwrap_err();
        assert!(err.to_string().contains("cycle"), "{err}");
        assert!(compile_query(&net, "n0", &[]).is_err());
    });
}

#[test]
fn prop_validator_rejects_incomplete_cpts() {
    check("validator rejects missing/duplicate CPT rows", 48, |rng| {
        let n = rng.range_usize(3, 8);
        let mut nodes = random_net_parts(rng, n);
        let victim = rng.below(n);
        if rng.bernoulli(0.5) || nodes[victim].cpt.len() == 1 {
            // Drop a random row -> wrong row count.
            let drop = rng.below(nodes[victim].cpt.len());
            nodes[victim].cpt.remove(drop);
            if nodes[victim].cpt.is_empty() {
                nodes[victim].cpt.push((0, 1.5)); // roots: out-of-range prob instead
            }
        } else {
            // Re-point one row at another assignment -> duplicate row.
            let a = nodes[victim].cpt[0].0;
            let last = nodes[victim].cpt.len() - 1;
            nodes[victim].cpt[last].0 = a;
        }
        let net = BayesNet::from_parts("defective", nodes);
        assert!(net.validate().is_err());
        assert!(compile_query(&net, "n0", &[]).is_err());
    });
}
