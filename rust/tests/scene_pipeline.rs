//! Acceptance suite for the streaming scene-parsing service layer
//! (`scene::pipeline`): hardware-vs-oracle detection rates, bit
//! determinism through the threaded pipeline, and the paper's 2,500 fps
//! virtual-hardware operating point.

use bayes_mem::scene::pipeline;
use bayes_mem::scene::{PipelineConfig, ScenarioSpec, VideoStats};

fn assert_stats_bitwise_eq(a: &VideoStats, b: &VideoStats, what: &str) {
    assert_eq!(a.frames, b.frames, "{what}: frames");
    assert_eq!(a.obstacles, b.obstacles, "{what}: obstacles");
    assert_eq!(a.rgb_detections, b.rgb_detections, "{what}: rgb detections");
    assert_eq!(a.thermal_detections, b.thermal_detections, "{what}: thermal detections");
    assert_eq!(a.fused_detections, b.fused_detections, "{what}: fused detections");
    assert_eq!(a.rgb_conf_sum.to_bits(), b.rgb_conf_sum.to_bits(), "{what}: rgb conf sum");
    assert_eq!(
        a.thermal_conf_sum.to_bits(),
        b.thermal_conf_sum.to_bits(),
        "{what}: thermal conf sum"
    );
    assert_eq!(
        a.fused_conf_sum.to_bits(),
        b.fused_conf_sum.to_bits(),
        "{what}: fused conf sum"
    );
}

/// Acceptance: per-scenario fused detection rates from the plan-served
/// hardware path land within 0.03 of the closed-form oracle at
/// 2^14-bit streams.
#[test]
fn hardware_rates_match_oracle_within_0_03_at_2_14_bits() {
    for spec in [
        ScenarioSpec::mixed_traffic(),
        ScenarioSpec::night_pedestrians(),
        ScenarioSpec::visibility_sweep(),
    ] {
        let name = spec.name;
        let cfg = PipelineConfig::deterministic(spec, 80, 777, 1 << 14);
        let r = pipeline::run(&cfg).unwrap();
        assert_eq!(r.hardware.frames, 80, "{name}");
        assert!(r.hardware.obstacles >= 80, "{name}: too few obstacles");
        assert_eq!(r.hardware.obstacles, r.oracle.obstacles, "{name}");
        // The single-modal counters come from the same sensor draws on
        // both paths — identical by construction.
        assert_eq!(r.hardware.rgb_detections, r.oracle.rgb_detections, "{name}");
        assert_eq!(r.hardware.thermal_detections, r.oracle.thermal_detections, "{name}");
        let gap = r.fused_rate_gap();
        assert!(
            gap <= 0.03,
            "{name}: hardware fused rate {:.4} vs oracle {:.4} (gap {gap:.4})",
            r.hardware.rate(r.hardware.fused_detections),
            r.oracle.rate(r.oracle.fused_detections),
        );
        assert_eq!(r.deadline_missed, 0, "{name}: deterministic preset has no deadline");
        // The scenario context plans really served network decisions.
        assert!(!r.context.is_empty(), "{name}");
        for c in &r.context {
            assert!((0.0..=1.0).contains(&c.posterior), "{name}: {c:?}");
            // Context decisions run under the anytime reliable stop, so
            // the value may be coarse — but the *decision side* of the
            // threshold is what the stop guarantees (z = 3), and the
            // scenario nets keep the exact posterior far from ½.
            assert_eq!(
                c.posterior > 0.5,
                c.exact > 0.5,
                "{name}: context {:?} hw {:.4} vs exact {:.4} flipped sides",
                c.visibility,
                c.posterior,
                c.exact
            );
            assert!(
                (c.posterior - c.exact).abs() < 0.25,
                "{name}: context {:?} hw {:.4} vs exact {:.4}",
                c.visibility,
                c.posterior,
                c.exact
            );
        }
    }
}

/// Acceptance: two runs on a shared seed produce bit-identical
/// `VideoStats` through the threaded pipeline (producer + submitter +
/// worker threads; the deterministic preset pins one submitter/worker
/// and no wall-clock deadline).
#[test]
fn pipeline_is_bit_deterministic_on_a_shared_seed() {
    let cfg = PipelineConfig::deterministic(ScenarioSpec::glare_burst(), 40, 99, 4096);
    assert!(cfg.is_deterministic());
    let a = pipeline::run(&cfg).unwrap();
    let b = pipeline::run(&cfg).unwrap();
    assert_stats_bitwise_eq(&a.hardware, &b.hardware, "hardware");
    assert_stats_bitwise_eq(&a.oracle, &b.oracle, "oracle");
    assert_eq!(a.by_visibility.len(), b.by_visibility.len());
    for ((va, ha, oa), (vb, hb, ob)) in a.by_visibility.iter().zip(&b.by_visibility) {
        assert_eq!(va, vb);
        assert_stats_bitwise_eq(ha, hb, "per-visibility hardware");
        assert_stats_bitwise_eq(oa, ob, "per-visibility oracle");
    }
    assert_eq!(a.context.len(), b.context.len());
    for (ca, cb) in a.context.iter().zip(&b.context) {
        assert_eq!(ca.visibility, cb.visibility);
        assert_eq!(
            ca.posterior.to_bits(),
            cb.posterior.to_bits(),
            "context posterior must be bit-identical"
        );
        assert_eq!(ca.exact.to_bits(), cb.exact.to_bits());
    }
    // Sanity that the pin bites: a different seed changes the stream.
    let other = pipeline::run(&PipelineConfig { seed: 100, ..cfg }).unwrap();
    assert_ne!(
        other.hardware.fused_conf_sum.to_bits(),
        a.hardware.fused_conf_sum.to_bits(),
        "different seeds must differ"
    );
}

/// The overlapped configuration (multiple submitters and workers) keeps
/// every frame accounted for and stays near the oracle — throughput
/// mode trades bit reproducibility, not correctness.
#[test]
fn threaded_pipeline_overlaps_and_stays_accurate() {
    let cfg = PipelineConfig {
        scenario: ScenarioSpec::mixed_traffic(),
        frames: 64,
        seed: 7,
        bits: 2048,
        workers: 2,
        submitters: 3,
        inflight_frames: 4,
        max_batch: 32,
        deadline: None,
        anytime: true,
        allow_partial: false,
        threshold: 0.5,
        fps_target: None,
        trace: false,
        metrics_out: None,
    };
    let r = pipeline::run(&cfg).unwrap();
    assert_eq!(r.hardware.frames, 64);
    assert_eq!(r.hardware.obstacles, r.oracle.obstacles);
    assert_eq!(r.deadline_missed, 0);
    assert!(r.fused_rate_gap() <= 0.06, "gap {:.4}", r.fused_rate_gap());
    // Prepare-once really held: one plan-cache miss for the fusion plan
    // plus one compile for the first visibility-conditioned context
    // network — the remaining conditions differ only in CPT values, so
    // they share that compile through structural rebinds. Zero
    // re-prepares on the hot path either way.
    assert_eq!(r.snapshot.plan_misses, 2, "fusion + first context structure");
    assert_eq!(
        r.snapshot.plan_rebinds,
        r.context.len() as u64 - 1,
        "every later context condition rebinds the shared structure"
    );
    assert_eq!(r.snapshot.plan_hits, 0);
    assert!(r.snapshot.completed > 0);
}

/// Acceptance: the default operating point (100-bit streams, batch 32,
/// 400 µs deadline, anytime on) sustains the paper's 2,500 fps
/// virtual-hardware decision rate.
#[test]
fn default_operating_point_sustains_2500_virtual_fps() {
    let cfg = PipelineConfig { frames: 48, fps_target: None, ..PipelineConfig::default() };
    assert_eq!(cfg.bits, 100);
    assert!(cfg.max_batch >= 32);
    assert!(cfg.anytime);
    let r = pipeline::run(&cfg).unwrap();
    assert!(
        r.hardware_fps >= 2_500.0,
        "virtual hardware fps {} below the paper's 2,500",
        r.hardware_fps
    );
    assert!(r.snapshot.completed > 0);
    assert!(r.wall_fps > 0.0);
}
