//! End-to-end tests for the TCP serving front door: live-socket
//! request/response for every decision kind, wire-level robustness
//! against hostile bytes, tenant isolation (namespaces, quotas,
//! metrics), and the overload SLO contract (shed vs blocking admission
//! at calibrated 1×/4× offered rates).

use std::io::Write;
use std::net::TcpStream;
use std::thread;
use std::time::{Duration, Instant};

use bayes_mem::config::{AdmissionPolicy, AppConfig};
use bayes_mem::device::WearPolicy;
use bayes_mem::serve::{
    loadgen, wire, Client, ErrorCode, Frame, Server, TenantSpec, WireParams, WirePolicy,
    WireSpec,
};

/// Wear rotation off: overload stages push banks past the endurance
/// budget by design.
fn test_config() -> AppConfig {
    let mut cfg = AppConfig::default();
    cfg.sne.wear_policy = WearPolicy::Ignore;
    cfg
}

fn inference_params() -> WireParams {
    WireParams::Inference { prior: 0.57, likelihood: 0.77, likelihood_not: 0.655 }
}

const NETWORK_TOML: &str = "[network]\nname = \"chain\"\n\n[nodes.fog]\nprior = 0.15\n\n\
[nodes.vis]\nparents = \"fog\"\ncpt = [0.9, 0.3]\n";

#[test]
fn wire_end_to_end_all_plan_kinds() {
    let server = Server::start("127.0.0.1:0", &test_config(), Vec::new()).unwrap();
    let addr = server.local_addr();
    let mut client = Client::connect(addr, "e2e").unwrap();
    let policy = WirePolicy { bits: Some(2048), ..WirePolicy::default() };

    let inference = client.prepare(WireSpec::Inference, policy).unwrap();
    let fusion = client.prepare(WireSpec::Fusion { modalities: 2 }, policy).unwrap();
    let network = client
        .prepare(
            WireSpec::Network {
                spec_toml: NETWORK_TOML.into(),
                query: "fog".into(),
                evidence: vec![("vis".into(), true)],
            },
            policy,
        )
        .unwrap();

    let d = client.decide(inference, inference_params()).unwrap();
    assert!(d.posterior > 0.0 && d.posterior < 1.0);
    assert!((d.posterior - d.exact).abs() < 0.2, "stochastic {} vs exact {}", d.posterior, d.exact);
    assert!(d.bits_used > 0);

    let d = client
        .decide(fusion, WireParams::Fusion { posteriors: vec![0.8, 0.7] })
        .unwrap();
    assert!(d.posterior > 0.5, "agreeing cues must reinforce, got {}", d.posterior);

    let d = client.decide(network, WireParams::Network { overrides: vec![] }).unwrap();
    assert!(d.posterior > 0.0 && d.posterior < 1.0);
    // P(fog | vis) must exceed the 0.15 prior (vis is strong evidence).
    assert!(d.exact > 0.15, "exact {}", d.exact);
    let exact_baked = d.exact;

    // The same plan with a per-decision prior override: the exact
    // reference moves with the binding, no re-prepare.
    let d = client
        .decide(
            network,
            WireParams::Network { overrides: vec![("fog".into(), 0, 0.6)] },
        )
        .unwrap();
    assert!(d.posterior > 0.0 && d.posterior < 1.0);
    assert!(d.exact > exact_baked, "raising the prior must raise the posterior: {}", d.exact);

    // Overrides failing plan validation are typed rejections.
    let err = client
        .decide(
            network,
            WireParams::Network { overrides: vec![("no-such-node".into(), 0, 0.5)] },
        )
        .unwrap_err();
    assert!(err.to_string().contains("unknown node"), "{err}");

    // Batch frame: answered in order, all on one plan.
    let batch: Vec<WireParams> = (0..16).map(|_| inference_params()).collect();
    let replies = client.decide_batch(inference, batch).unwrap();
    assert_eq!(replies.len(), 16);
    for r in replies {
        let d = r.expect("batch entry failed");
        assert!(d.posterior > 0.0 && d.posterior < 1.0);
    }

    // Typed deadline miss: a 1 µs budget on a long sweep cannot be met.
    let doomed = client
        .prepare(
            WireSpec::Inference,
            WirePolicy { deadline_us: Some(1), bits: Some(1 << 20), ..WirePolicy::default() },
        )
        .unwrap();
    match client.decide_raw(doomed, inference_params()).unwrap() {
        Err((ErrorCode::Deadline, _)) => {}
        other => panic!("expected typed deadline miss, got {other:?}"),
    }
    let snap = server.tenant_snapshot("e2e").unwrap();
    assert!(snap.deadline_missed >= 1);

    // Unknown plan ids are typed, not fatal.
    match client.decide_raw(9999, inference_params()).unwrap() {
        Err((ErrorCode::UnknownPlan, _)) => {}
        other => panic!("expected unknown-plan, got {other:?}"),
    }

    // Per-tenant metrics over the wire, labeled with the tenant id.
    let text = client.metrics_text().unwrap();
    assert!(text.contains("tenant=\"e2e\""), "{text}");
    assert!(text.contains("tenant_decisions_completed_total"), "{text}");

    // Wire shutdown: acknowledged, then the server unwinds.
    client.shutdown_server().unwrap();
    assert!(server.shutdown_requested());
    server.run().unwrap();
}

/// Raw 12-byte header (magic ‖ version ‖ ftype ‖ tenant_len ‖ reserved
/// ‖ payload_len LE).
fn raw_header(version: u8, ftype: u8, tenant_len: u8, payload_len: u32) -> [u8; 12] {
    let mut h = [0u8; 12];
    h[..4].copy_from_slice(&wire::MAGIC);
    h[4] = version;
    h[5] = ftype;
    h[6] = tenant_len;
    h[8..12].copy_from_slice(&payload_len.to_le_bytes());
    h
}

fn expect_error_frame(stream: &mut TcpStream, want: ErrorCode) {
    match wire::read_frame(stream) {
        Ok((_, Frame::Error { code, .. })) => assert_eq!(code, want),
        other => panic!("expected {want:?} error frame, got {other:?}"),
    }
}

#[test]
fn hostile_bytes_get_typed_errors_and_the_server_survives() {
    let server = Server::start("127.0.0.1:0", &test_config(), Vec::new()).unwrap();
    let addr = server.local_addr();

    // Garbage magic: typed malformed error, then the (desynchronized)
    // connection closes.
    let mut s = TcpStream::connect(addr).unwrap();
    s.write_all(b"XXXXXXXXXXXXXXXX").unwrap();
    expect_error_frame(&mut s, ErrorCode::Malformed);
    assert!(wire::read_frame(&mut s).is_err(), "desynchronized stream must close");

    // Wrong protocol version: typed error, connection closes.
    let mut s = TcpStream::connect(addr).unwrap();
    s.write_all(&raw_header(wire::VERSION + 1, 0x04, 0, 0)).unwrap();
    expect_error_frame(&mut s, ErrorCode::WrongVersion);
    assert!(wire::read_frame(&mut s).is_err());

    // Oversized declared payload: rejected up front — the reply arrives
    // even though we never send a single payload byte, so the server
    // cannot have tried to read (or allocate) the declared megabytes.
    let mut s = TcpStream::connect(addr).unwrap();
    s.write_all(&raw_header(wire::VERSION, 0x02, 0, wire::MAX_PAYLOAD + 1)).unwrap();
    expect_error_frame(&mut s, ErrorCode::Oversized);
    assert!(wire::read_frame(&mut s).is_err());

    // Well-framed but undecodable payload: typed error AND the
    // connection stays frame-aligned — a valid request still works.
    let mut s = TcpStream::connect(addr).unwrap();
    let junk = [0xABu8; 8];
    s.write_all(&raw_header(wire::VERSION, 0x02, 4, junk.len() as u32)).unwrap();
    s.write_all(b"fuzz").unwrap();
    s.write_all(&junk).unwrap();
    expect_error_frame(&mut s, ErrorCode::Malformed);
    wire::write_frame(&mut s, "fuzz", &Frame::Metrics).unwrap();
    match wire::read_frame(&mut s) {
        Ok((_, Frame::MetricsText(text))) => assert!(text.contains("tenant=\"fuzz\"")),
        other => panic!("connection should have recovered, got {other:?}"),
    }

    // Unknown frame type: same recoverable contract.
    let mut s = TcpStream::connect(addr).unwrap();
    s.write_all(&raw_header(wire::VERSION, 0x77, 4, 2)).unwrap();
    s.write_all(b"fuzz\0\0").unwrap();
    expect_error_frame(&mut s, ErrorCode::UnknownFrame);
    wire::write_frame(&mut s, "fuzz", &Frame::Metrics).unwrap();
    assert!(matches!(wire::read_frame(&mut s), Ok((_, Frame::MetricsText(_)))));

    // Mid-frame disconnect: drop after half a header.
    {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(&raw_header(wire::VERSION, 0x02, 0, 64)[..5]).unwrap();
    }
    // A response frame sent as a request: typed, recoverable.
    let mut s = TcpStream::connect(addr).unwrap();
    wire::write_frame(&mut s, "fuzz", &Frame::Prepared { plan: 1 }).unwrap();
    expect_error_frame(&mut s, ErrorCode::Malformed);

    // After all of the above, the server still serves real work.
    let mut client = Client::connect(addr, "survivor").unwrap();
    let plan = client.prepare(WireSpec::Inference, WirePolicy::default()).unwrap();
    assert!(client.decide(plan, inference_params()).unwrap().posterior > 0.0);
    server.shutdown().unwrap();
}

#[test]
fn tenant_namespaces_quotas_and_metrics_are_isolated() {
    let mut cfg = test_config();
    cfg.serve.shards = 2;
    let hog = TenantSpec {
        name: "hog".into(),
        admission: AdmissionPolicy::Shed,
        max_inflight: 4,
        max_plans: 2,
        plan_cache_capacity: 2,
    };
    let server = Server::start("127.0.0.1:0", &cfg, vec![hog]).unwrap();
    let addr = server.local_addr();

    // The quiet tenant registers a plan and does a little work.
    let mut quiet = Client::connect(addr, "quiet").unwrap();
    let quiet_plan = quiet.prepare(WireSpec::Inference, WirePolicy::default()).unwrap();
    for _ in 0..5 {
        quiet.decide(quiet_plan, inference_params()).unwrap();
    }

    // The hog exhausts its plan quota; the third prepare is a typed
    // quota error, not a failure of anyone else's namespace.
    let mut hog = Client::connect(addr, "hog").unwrap();
    let hog_plan = hog.prepare(WireSpec::Inference, WirePolicy::default()).unwrap();
    hog.prepare(WireSpec::Fusion { modalities: 2 }, WirePolicy::default()).unwrap();
    let err = hog
        .prepare(WireSpec::Fusion { modalities: 3 }, WirePolicy::default())
        .unwrap_err();
    assert!(err.to_string().contains("quota-exhausted"), "{err}");

    // Plan ids are namespaced per tenant: both tenants hold an id `1`,
    // and an id only the hog registered is unknown to the quiet tenant.
    assert_eq!(quiet_plan, 1);
    assert_eq!(hog_plan, 1);
    match quiet.decide_raw(2, inference_params()).unwrap() {
        Err((ErrorCode::UnknownPlan, _)) => {}
        other => panic!("plan 2 must not leak across tenants, got {other:?}"),
    }

    // Both tenants still decide fine on their own plans after the
    // hog's quota exhaustion.
    assert!(hog.decide(hog_plan, inference_params()).unwrap().posterior > 0.0);
    assert!(quiet.decide(quiet_plan, inference_params()).unwrap().posterior > 0.0);

    // Metrics are isolated: the quiet tenant's registry saw exactly its
    // own traffic (6 decisions), none of the hog's submissions or
    // rejections.
    let quiet_snap = server.tenant_snapshot("quiet").unwrap();
    assert_eq!(quiet_snap.submitted, 6);
    assert_eq!(quiet_snap.completed, 6);
    assert_eq!(quiet_snap.rejected, 0);
    let hog_snap = server.tenant_snapshot("hog").unwrap();
    assert!(hog_snap.rejected >= 1, "the quota rejection must land on the hog");
    server.shutdown().unwrap();
}

/// Outcome tallies plus reply latencies (measured from the *scheduled*
/// arrival) for one open-loop stage of one tenant.
#[derive(Default)]
struct StageOutcome {
    ok: u64,
    shed: u64,
    other: u64,
    latencies_ns: Vec<u64>,
}

impl StageOutcome {
    fn p99_ns(&self) -> u64 {
        let mut v = self.latencies_ns.clone();
        assert!(!v.is_empty(), "stage produced no replies");
        v.sort_unstable();
        v[(v.len() - 1) * 99 / 100]
    }
}

/// Drive `n` open-loop arrivals at `rate_rps` across `conns`
/// connections (connection `i` owns arrivals `i, i+conns, …`). Every
/// reply — decision or typed shed — is timed from its scheduled
/// arrival, so schedule slip shows up as latency.
fn drive(
    addr: std::net::SocketAddr,
    tenant: &str,
    plan: u32,
    conns: usize,
    rate_rps: f64,
    n: u64,
) -> StageOutcome {
    let interval = Duration::from_secs_f64(1.0 / rate_rps);
    let start = Instant::now() + Duration::from_millis(5);
    let mut threads = Vec::new();
    for i in 0..conns {
        let tenant = tenant.to_string();
        threads.push(thread::spawn(move || {
            let mut client = Client::connect(addr, &tenant).unwrap();
            let mut out = StageOutcome::default();
            let mut j = i as u64;
            while j < n {
                let target = start + interval.mul_f64(j as f64);
                let now = Instant::now();
                if target > now {
                    thread::sleep(target - now);
                }
                match client.decide_raw(plan, inference_params()).unwrap() {
                    Ok(_) => out.ok += 1,
                    Err((ErrorCode::QuotaExhausted | ErrorCode::Backpressure, _)) => {
                        out.shed += 1
                    }
                    Err(_) => out.other += 1,
                }
                out.latencies_ns.push(target.elapsed().as_nanos() as u64);
                j += conns as u64;
            }
            out
        }));
    }
    let mut total = StageOutcome::default();
    for t in threads {
        let part = t.join().unwrap();
        total.ok += part.ok;
        total.shed += part.shed;
        total.other += part.other;
        total.latencies_ns.extend(part.latencies_ns);
    }
    total
}

/// The overload SLO contract: under 4× overload a shed-policy tenant
/// (tight in-flight quota, shed admission) keeps its p99 reply latency
/// within 2× of its 1× value (plus an absolute floor absorbing CI
/// noise), while a blocking tenant on its own shard absorbs the whole
/// backlog — zero rejections, every request answered — and pays for it
/// in schedule slip. Offered rates are calibrated against the measured
/// closed-loop service time so the 4× stage genuinely oversubscribes
/// the shard on any machine.
#[test]
fn overload_slo_shed_tenant_stays_flat_while_blocking_tenant_absorbs() {
    let mut cfg = test_config();
    cfg.serve.shards = 2;
    cfg.coordinator.workers = 1;
    // Long sweeps make the per-decision service time dominate socket /
    // scheduler noise.
    let policy = WirePolicy { bits: Some(200_000), ..WirePolicy::default() };

    // Pick tenant names pinned to *different* shards, so the blocking
    // tenant's backlog cannot sit in front of the shed tenant's work.
    let probe = Server::start("127.0.0.1:0", &cfg, Vec::new()).unwrap();
    let shed_name = "shed-tenant".to_string();
    let block_name = (0..100)
        .map(|i| format!("block-tenant-{i}"))
        .find(|n| probe.shard_of(n) != probe.shard_of(&shed_name))
        .expect("some candidate must hash to the other shard");
    probe.shutdown().unwrap();

    let tenants = vec![
        TenantSpec {
            name: shed_name.clone(),
            admission: AdmissionPolicy::Shed,
            max_inflight: 2,
            max_plans: 8,
            plan_cache_capacity: 8,
        },
        TenantSpec {
            name: block_name.clone(),
            admission: AdmissionPolicy::Block,
            max_inflight: 4096,
            max_plans: 8,
            plan_cache_capacity: 8,
        },
    ];
    let server = Server::start("127.0.0.1:0", &cfg, tenants).unwrap();
    let addr = server.local_addr();

    // Register one plan per tenant and calibrate the closed-loop
    // service time on the shed tenant's shard.
    let mut shed_client = Client::connect(addr, &shed_name).unwrap();
    let shed_plan = shed_client.prepare(WireSpec::Inference, policy).unwrap();
    let mut block_client = Client::connect(addr, &block_name).unwrap();
    let block_plan = block_client.prepare(WireSpec::Inference, policy).unwrap();
    let mut samples: Vec<u64> = (0..15)
        .map(|_| {
            let t0 = Instant::now();
            shed_client.decide(shed_plan, inference_params()).unwrap();
            t0.elapsed().as_nanos() as u64
        })
        .collect();
    samples.sort_unstable();
    let service_ns = samples[samples.len() / 2].max(50_000);
    let capacity_rps = 1e9 / service_ns as f64;
    let rate_1x = 0.5 * capacity_rps;
    let rate_4x = 4.0 * rate_1x;
    let (n_1x, n_4x) = (60u64, 240u64);

    // Stage 1: nominal load, both tenants concurrently.
    let shed_h = {
        let (a, t) = (addr, shed_name.clone());
        thread::spawn(move || drive(a, &t, shed_plan, 4, rate_1x, n_1x))
    };
    let block_1x = drive(addr, &block_name, block_plan, 8, rate_1x, n_1x);
    let shed_1x = shed_h.join().unwrap();

    // Stage 2: 4× overload — double the shard's capacity — both
    // tenants concurrently.
    let shed_h = {
        let (a, t) = (addr, shed_name.clone());
        thread::spawn(move || drive(a, &t, shed_plan, 4, rate_4x, n_4x))
    };
    let block_4x = drive(addr, &block_name, block_plan, 8, rate_4x, n_4x);
    let shed_4x = shed_h.join().unwrap();

    // The shed tenant actually shed under overload, and never saw a
    // transport or internal failure.
    assert!(shed_4x.shed > 0, "4x overload must trigger quota sheds");
    assert_eq!(shed_1x.other + shed_4x.other, 0);
    assert!(shed_1x.ok > 0 && shed_4x.ok > 0);

    // SLO pin: p99 reply latency at 4× within 2× of the 1× value
    // (10 ms absolute floor absorbs scheduler noise on loaded CI).
    let (p99_1x, p99_4x) = (shed_1x.p99_ns(), shed_4x.p99_ns());
    let budget = (2 * p99_1x).max(10_000_000);
    assert!(
        p99_4x <= budget,
        "shed tenant p99 blew up under overload: {p99_4x} ns vs budget {budget} ns \
         (1x p99 {p99_1x} ns, service {service_ns} ns)"
    );

    // The blocking tenant absorbed everything: no rejections, every
    // arrival answered with a decision — and the backlog shows up as
    // schedule slip at 4×.
    assert_eq!(block_1x.shed + block_4x.shed, 0, "blocking tenant must never shed");
    assert_eq!(block_1x.other + block_4x.other, 0);
    assert_eq!(block_1x.ok, n_1x);
    assert_eq!(block_4x.ok, n_4x);
    assert!(
        block_4x.p99_ns() > 4 * block_1x.p99_ns(),
        "2x-capacity oversubscription must show up as slip: 4x p99 {} ns vs 1x p99 {} ns",
        block_4x.p99_ns(),
        block_1x.p99_ns()
    );
    let snap = server.tenant_snapshot(&block_name).unwrap();
    assert_eq!(snap.rejected, 0);

    server.shutdown().unwrap();
}

/// Aggregate serving throughput: batched wire decisions across two
/// tenants must clear the paper's 2,500 decisions/s line end to end
/// (TCP hop, sharded dispatch, stochastic execution).
#[test]
fn aggregate_wire_throughput_clears_2500_dps() {
    let mut cfg = test_config();
    cfg.serve.shards = 2;
    cfg.coordinator.workers = 2;
    let server = Server::start("127.0.0.1:0", &cfg, Vec::new()).unwrap();
    let addr = server.local_addr();
    let policy = WirePolicy { bits: Some(64), ..WirePolicy::default() };

    const BATCHES: usize = 16;
    const BATCH: usize = 256;
    let t0 = Instant::now();
    let threads: Vec<_> = ["tp-a", "tp-b"]
        .into_iter()
        .map(|tenant| {
            thread::spawn(move || {
                let mut client = Client::connect(addr, tenant).unwrap();
                let plan = client.prepare(WireSpec::Inference, policy).unwrap();
                let mut ok = 0u64;
                for _ in 0..BATCHES {
                    let batch: Vec<WireParams> =
                        (0..BATCH).map(|_| inference_params()).collect();
                    for r in client.decide_batch(plan, batch).unwrap() {
                        r.expect("batch entry failed");
                        ok += 1;
                    }
                }
                ok
            })
        })
        .collect();
    let total: u64 = threads.into_iter().map(|t| t.join().unwrap()).sum();
    let rate = total as f64 / t0.elapsed().as_secs_f64();
    assert_eq!(total, (2 * BATCHES * BATCH) as u64);
    assert!(rate >= 2_500.0, "aggregate wire throughput {rate:.0} decisions/s < 2500");
    server.shutdown().unwrap();
}

#[test]
fn loadgen_sweep_reports_and_exports_slo_metrics() {
    let mut cfg = test_config();
    cfg.serve.shards = 2;
    let server = Server::start("127.0.0.1:0", &cfg, Vec::new()).unwrap();
    let lg = loadgen::LoadgenConfig {
        addr: server.local_addr().to_string(),
        connections: 4,
        rate: 2_000.0,
        requests: 300,
        overloads: vec![1.0, 2.0],
        ..loadgen::LoadgenConfig::default()
    };
    let report = loadgen::run(&lg).unwrap();
    assert_eq!(report.stages.len(), 2);
    assert_eq!(report.stages[0].sent, 300);
    assert_eq!(report.stages[1].sent, 600, "2x stage scales the schedule");
    for s in &report.stages {
        assert_eq!(s.sent, s.ok + s.shed + s.deadline_missed + s.other_errors);
        assert_eq!(s.other_errors, 0, "stage {} saw transport errors", s.label());
        assert!(s.p99_us >= s.p50_us);
    }
    assert!(report.saturation_rps > 0.0);

    let dir = std::env::temp_dir().join(format!("bayes_mem_serving_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("BENCH_serving.json");
    report.export_json(&path).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    for key in [
        "\"p99_latency_us\"",
        "\"deadline_miss_rate\"",
        "\"saturation_throughput_rps\"",
        "\"p999_latency_us_2x\"",
    ] {
        assert!(text.contains(key), "export missing {key}: {text}");
    }
    std::fs::remove_dir_all(&dir).ok();
    server.shutdown().unwrap();
}
