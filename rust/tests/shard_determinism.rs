//! Intra-decision sharding determinism (ISSUE-9 acceptance):
//!
//! * the shard-parallel evaluator must be **bit-identical** to the
//!   single-thread sweep — posteriors, energy/time ledgers, and anytime
//!   stop decisions — at every thread budget, on shared seeds, including
//!   stream lengths that do not divide evenly into blocks or shards;
//! * `drift_coupling != 0` (staged nonideal encode) falls back to the
//!   single-shard path rather than silently changing device semantics;
//! * the `[coordinator] intra_decision_threads` knob is validated as a
//!   typed config error (0 and oversubscription both rejected), and the
//!   coordinator serves bit-identical decision streams under it.

use std::time::Duration;

use bayes_mem::config::AppConfig;
use bayes_mem::coordinator::{Coordinator, Decision, DecisionParams, PlanSpec, Policy};
use bayes_mem::device::WearPolicy;
use bayes_mem::network::{compile_query, BayesNet, NetlistEvaluator, StopPolicy};
use bayes_mem::stochastic::{SneBank, SneConfig};
use bayes_mem::util::tomlmini::Document;
use bayes_mem::Error;

fn bank(n_bits: usize, seed: u64) -> SneBank {
    let cfg = SneConfig { n_bits, wear_policy: WearPolicy::Ignore, ..Default::default() };
    SneBank::new(cfg, seed).unwrap()
}

/// A 5-node diamond-ish scene exercising shared parent streams, a
/// 2-parent MUX tree, and an evidence-conditioned CORDIV readout.
fn scene() -> BayesNet {
    let mut net = BayesNet::named("shard_scene");
    net.add_root("fog", 0.3).unwrap();
    net.add_root("night", 0.45).unwrap();
    net.add_node("visibility", &["fog", "night"], &[0.9, 0.55, 0.5, 0.1]).unwrap();
    net.add_node("detection", &["visibility"], &[0.2, 0.85]).unwrap();
    net.add_node("alarm", &["detection"], &[0.08, 0.9]).unwrap();
    net
}

#[test]
fn sharded_sweeps_are_bit_identical_across_thread_budgets() {
    let net = scene();
    let netlist = compile_query(&net, "fog", &[("alarm", true)]).unwrap();
    // Odd lengths on purpose: 1000 bits is a partial last word, 5000
    // bits is a partial last block, 8192 is block- and shard-aligned.
    for n_bits in [1000usize, 4096, 5000, 8192] {
        let mut eval = NetlistEvaluator::new();
        let mut b1 = bank(n_bits, 99);
        let base = eval.evaluate(&mut b1, &netlist).unwrap();
        assert_eq!(eval.last_shards(), 1);
        let ledger1 = b1.ledger().clone();
        for threads in [2usize, 8] {
            let mut ev = NetlistEvaluator::new();
            ev.set_threads(threads);
            let mut bt = bank(n_bits, 99);
            let out = ev.evaluate(&mut bt, &netlist).unwrap();
            // f64 equality on purpose: sharding must be bit-exact.
            assert_eq!(out.posterior, base.posterior, "{n_bits} bits x {threads} threads");
            assert_eq!(out.marginal, base.marginal, "{n_bits} bits x {threads} threads");
            let lt = bt.ledger();
            assert_eq!(lt.pulses, ledger1.pulses, "{n_bits} bits x {threads} threads");
            assert_eq!(
                lt.switch_events, ledger1.switch_events,
                "{n_bits} bits x {threads} threads"
            );
            assert_eq!(
                lt.energy_nj.to_bits(),
                ledger1.energy_nj.to_bits(),
                "{n_bits} bits x {threads} threads: energy must match to the bit"
            );
        }
    }
}

#[test]
fn anytime_stop_decisions_match_at_every_thread_budget() {
    let net = scene();
    let netlist = compile_query(&net, "fog", &[("alarm", true)]).unwrap();
    let policy = StopPolicy::converged(0.02);
    let mut eval = NetlistEvaluator::new();
    let mut b1 = bank(32_768, 7);
    let base = eval.evaluate_anytime(&mut b1, &netlist, netlist.inputs(), &policy).unwrap();
    for threads in [2usize, 8] {
        let mut ev = NetlistEvaluator::new();
        ev.set_threads(threads);
        let mut bt = bank(32_768, 7);
        let out = ev.evaluate_anytime(&mut bt, &netlist, netlist.inputs(), &policy).unwrap();
        assert_eq!(out.posterior, base.posterior, "{threads} threads");
        assert_eq!(out.bits_used, base.bits_used, "{threads} threads: stop point moved");
        assert_eq!(out.stop, base.stop, "{threads} threads: stop reason changed");
        assert_eq!(out.half_width, base.half_width, "{threads} threads");
    }
}

#[test]
fn drift_coupling_falls_back_to_single_shard() {
    let net = scene();
    let netlist = compile_query(&net, "fog", &[("alarm", true)]).unwrap();
    let mut cfg = SneConfig { n_bits: 4096, wear_policy: WearPolicy::Ignore, ..Default::default() };
    cfg.params.drift_coupling = 0.05;
    let mut b1 = SneBank::new(cfg.clone(), 5).unwrap();
    let mut eval = NetlistEvaluator::new();
    let base = eval.evaluate(&mut b1, &netlist).unwrap();
    let mut bt = SneBank::new(cfg, 5).unwrap();
    let mut ev = NetlistEvaluator::new();
    ev.set_threads(8);
    let out = ev.evaluate(&mut bt, &netlist).unwrap();
    assert_eq!(ev.last_shards(), 1, "nonideal devices must stage on one shard");
    assert_eq!(out.posterior, base.posterior);
    assert_eq!(bt.ledger().energy_nj.to_bits(), b1.ledger().energy_nj.to_bits());
}

#[test]
fn intra_decision_threads_knob_is_validated() {
    // 0 is a typed config error.
    let doc = Document::parse("[coordinator]\nintra_decision_threads = 0").unwrap();
    let err = AppConfig::from_document(&doc).unwrap_err();
    assert!(matches!(err, Error::Config(_)), "{err}");
    assert!(err.to_string().contains("intra_decision_threads"), "{err}");
    // Oversubscription beyond the machine is rejected the same way.
    if std::thread::available_parallelism().is_ok() {
        let doc = Document::parse("[coordinator]\nintra_decision_threads = 65536").unwrap();
        let err = AppConfig::from_document(&doc).unwrap_err();
        assert!(matches!(err, Error::Config(_)), "{err}");
    }
    // 1 (the default) always validates.
    let doc = Document::parse("[coordinator]\nintra_decision_threads = 1").unwrap();
    assert_eq!(AppConfig::from_document(&doc).unwrap().coordinator.intra_decision_threads, 1);
}

/// Serve the same decision stream through a 1-worker coordinator at two
/// intra-decision thread budgets; the replies must be bit-identical.
fn serve_with_threads(threads: usize, bits: usize) -> Vec<Decision> {
    let mut cfg = AppConfig::default();
    cfg.seed = 4242;
    cfg.coordinator.workers = 1;
    cfg.coordinator.intra_decision_threads = threads;
    let coord = Coordinator::start(&cfg).unwrap();
    let h = coord.handle();
    let plan = h
        .prepare(PlanSpec::Inference)
        .unwrap()
        .with_policy(Policy { bits: Some(bits), ..Policy::default() });
    let pending: Vec<_> = (0..12)
        .map(|i| {
            let x = (i as f64 + 0.5) / 12.0;
            plan.submit(DecisionParams::Inference {
                prior: 0.2 + 0.6 * x,
                likelihood: 0.9 - 0.5 * x,
                likelihood_not: 0.2 + 0.4 * x,
            })
            .unwrap()
        })
        .collect();
    let out = pending
        .into_iter()
        .map(|p| p.wait_timeout(Duration::from_secs(30)).unwrap())
        .collect();
    coord.shutdown();
    out
}

#[test]
fn coordinator_decisions_are_bit_identical_under_the_thread_knob() {
    let threads = match std::thread::available_parallelism() {
        Ok(n) if n.get() >= 2 => 2,
        _ => return, // single-core runner: nothing to compare
    };
    let base = serve_with_threads(1, 4096);
    let sharded = serve_with_threads(threads, 4096);
    assert_eq!(base.len(), sharded.len());
    for (i, (a, b)) in base.iter().zip(&sharded).enumerate() {
        assert_eq!(a.posterior, b.posterior, "decision {i} diverged under sharding");
        assert_eq!(a.exact, b.exact, "decision {i}");
    }
}
